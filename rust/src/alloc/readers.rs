//! Cross-process reader support: the lease-and-pin registry and the
//! epoch-side data store that together give a live reader a stable view
//! of one committed epoch (paper §3.2/§3.6 — many processes over one
//! datastore — rebuilt on the segmented-manifest machinery).
//!
//! ## Leases
//!
//! Every attached reader owns one file under `<store>/readers/`
//! (`lease-<pid>-<n>`) and holds an **exclusive `flock`** on it for the
//! lifetime of the attach. The file body is a tiny checksummed record of
//! the epoch the reader has pinned. Liveness is the lock itself: anyone
//! probing the registry tries a non-blocking exclusive `flock` on each
//! lease — acquiring it proves the owner is gone (the kernel releases
//! `flock`s when the holding process dies, kill-9 included) and the
//! lease is reaped on the spot; `EWOULDBLOCK` proves the reader is live
//! and its pinned epoch must be honored.
//!
//! [`crate::alloc::mgmt_io::gc`] consults [`scan_pins`] so a pinned
//! epoch's manifest and the section files it references are never
//! deleted while the lease is live. A lease whose record cannot be read
//! back (torn write, version skew) pins **everything** — deletion is
//! the unrecoverable direction, so the registry fails conservative.
//! [`PIN_ALL`] is also written deliberately while a reader is between
//! epochs (mid-attach, mid-refresh) to close the race where GC lists
//! the registry an instant before the reader records its choice.
//!
//! ## Epoch-side chunk copies
//!
//! A reader maps the segment's backing files `MAP_SHARED`; the page
//! cache is shared with the owner, so the live files show the owner's
//! in-flight writes immediately — `msync` timing cannot help. The only
//! way a pinned view stays stable is to back it with **different
//! inodes**: before the flusher's in-place `msync` may tear a pinned
//! view, it reflinks each dirty chunk's range into
//! `<store>/epoch-side/side-c<chunk>-e<epoch>.bin`
//! ([`crate::storage::reflink::clone_file_range`]; plain copy where the
//! filesystem cannot reflink), and the attached reader's mapping
//! resolves chunks to these side files instead of the live ones. A
//! freshly attaching reader seeds its own side copies from the live
//! bytes (staleness < 1 epoch: the bytes are between its pinned epoch's
//! commit and the next); after that, `refresh()` walks forward on the
//! flusher-produced copies alone. Side files are immutable once their
//! epoch has committed, and a mapped side file survives its own unlink,
//! so GC (which keeps, per chunk, the newest copy at or below every
//! protected epoch) can never yank pages out from under a reader.
//!
//! With the pipelined flusher, the freeze runs at **cut** time
//! (`prepare_epoch`), so side copies for more than one not-yet-committed
//! epoch may coexist on disk at once — each tagged with the epoch whose
//! cut produced it. Readers only ever resolve to epochs named by a
//! committed manifest, so copies tagged with an epoch that was later
//! aborted are simply never referenced and are collected the next time a
//! later epoch commits (GC keeps everything newer than the max protected
//! epoch, which covers the still-in-flight tags).

use std::collections::HashMap;
use std::fs::{self, File, OpenOptions};
use std::os::unix::io::AsRawFd;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};
use crate::storage::faults;
use crate::storage::reflink;
use crate::storage::segment::SegmentStorage;

/// Registry directory inside the datastore.
pub const READERS_DIR: &str = "readers";
/// Epoch-side chunk-copy directory inside the datastore.
pub const SIDE_DIR: &str = "epoch-side";
/// Lease epoch meaning "pin everything" (reader between epochs).
pub const PIN_ALL: u64 = u64::MAX;

const LEASE_MAGIC: &[u8; 8] = b"METALLRL";
const LEASE_LEN: usize = 24; // magic + epoch + fnv1a(magic+epoch)

/// Distinguishes multiple leases taken by one process (tests, one
/// process attaching several stores or several readers).
static LEASE_SEQ: AtomicU64 = AtomicU64::new(0);

// ---------------------------------------------------------- flock ----

/// Try to take an `flock` on `file`. Returns `Ok(true)` when acquired,
/// `Ok(false)` on `EWOULDBLOCK` (someone else holds a conflicting
/// lock). The lock lives until the file description is closed and is
/// released by the kernel if the holder dies.
pub(crate) fn flock_try(file: &File, exclusive: bool) -> Result<bool> {
    let op = if exclusive { libc::LOCK_EX } else { libc::LOCK_SH } | libc::LOCK_NB;
    let rc = unsafe { libc::flock(file.as_raw_fd(), op) };
    if rc == 0 {
        return Ok(true);
    }
    match std::io::Error::last_os_error().raw_os_error() {
        Some(code) if code == libc::EWOULDBLOCK || code == libc::EAGAIN => Ok(false),
        _ => Err(Error::sys("flock")),
    }
}

// ---------------------------------------------------------- leases ----

fn readers_dir(store: &Path) -> PathBuf {
    store.join(READERS_DIR)
}

fn encode_lease(epoch: u64) -> [u8; LEASE_LEN] {
    let mut buf = [0u8; LEASE_LEN];
    buf[0..8].copy_from_slice(LEASE_MAGIC);
    buf[8..16].copy_from_slice(&epoch.to_le_bytes());
    let sum = crate::alloc::mgmt_io::fnv1a(&buf[0..16]);
    buf[16..24].copy_from_slice(&sum.to_le_bytes());
    buf
}

fn decode_lease(buf: &[u8]) -> Option<u64> {
    if buf.len() != LEASE_LEN || &buf[0..8] != LEASE_MAGIC {
        return None;
    }
    let sum = u64::from_le_bytes(buf[16..24].try_into().ok()?);
    if crate::alloc::mgmt_io::fnv1a(&buf[0..16]) != sum {
        return None;
    }
    Some(u64::from_le_bytes(buf[8..16].try_into().ok()?))
}

/// One reader's lease: a registry file held under exclusive `flock`
/// recording the pinned epoch. Dropping releases the lock and removes
/// the file; a kill-9 leaves the file behind for [`scan_pins`] to reap.
pub struct ReaderLease {
    path: PathBuf,
    file: File,
    epoch: u64,
}

impl ReaderLease {
    /// Create and lock a fresh lease in `store`, pinned to [`PIN_ALL`]
    /// (the caller re-pins once it has chosen a manifest).
    pub fn acquire(store: &Path) -> Result<Self> {
        let dir = readers_dir(store);
        fs::create_dir_all(&dir).map_err(|e| Error::io(&dir, e))?;
        let pid = std::process::id();
        let seq = LEASE_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = dir.join(format!("lease-{pid}-{seq}"));
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .map_err(|e| Error::io(&path, e))?;
        if !flock_try(&file, true)? {
            // our own path collided with a live lease — cannot happen
            // with the pid+seq name unless pids recycled mid-lease
            return Err(Error::Datastore(format!(
                "reader lease {path:?} is already held by another process"
            )));
        }
        let mut lease = Self { path, file, epoch: PIN_ALL };
        lease.write_record(PIN_ALL)?;
        Ok(lease)
    }

    fn write_record(&mut self, epoch: u64) -> Result<()> {
        let buf = encode_lease(epoch);
        faults::write_full_at(&self.file, &buf, 0, faults::Site::Lease)
            .map_err(|e| Error::io(&self.path, e))?;
        // No fsync: cross-process visibility is page-cache-immediate,
        // and a reader crash makes the lease stale regardless of what
        // the record says.
        self.epoch = epoch;
        Ok(())
    }

    /// Re-pin the lease to `epoch` (or [`PIN_ALL`] while transitioning).
    pub fn pin(&mut self, epoch: u64) -> Result<()> {
        self.write_record(epoch)
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for ReaderLease {
    fn drop(&mut self) {
        // unlink first, then the fd close releases the flock — a prober
        // can never acquire the lock while the file is still listed
        let _ = fs::remove_file(&self.path);
    }
}

/// What a registry scan found (after reaping stale leases).
#[derive(Clone, Debug, Default)]
pub struct PinScan {
    /// Distinct pinned epochs of live leases ([`PIN_ALL`] excluded).
    pub epochs: Vec<u64>,
    /// A live lease pins everything: mid-transition ([`PIN_ALL`]) or a
    /// record that failed to decode. GC must delete nothing epoch-like.
    pub pin_all: bool,
    /// Live leases seen.
    pub live: usize,
    /// Stale leases reaped by this scan.
    pub reaped: usize,
}

impl PinScan {
    pub fn any_live(&self) -> bool {
        self.live > 0
    }
}

/// Scan the registry: reap stale leases (liveness probe = non-blocking
/// exclusive `flock`; the kernel dropped a dead reader's lock), collect
/// the pinned epochs of live ones. Errors are absorbed conservatively:
/// anything unreadable that cannot be proven stale counts as live and
/// pin-all.
pub fn scan_pins(store: &Path) -> PinScan {
    let mut out = PinScan::default();
    let dir = readers_dir(store);
    let Ok(rd) = fs::read_dir(&dir) else { return out };
    for entry in rd.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if !name.starts_with("lease-") {
            continue;
        }
        let path = entry.path();
        let Ok(file) = OpenOptions::new().read(true).open(&path) else {
            // raced with the holder's own unlink
            continue;
        };
        match flock_try(&file, true) {
            Ok(true) => {
                // we hold the lock: the reader is gone — reap
                let _ = fs::remove_file(&path);
                out.reaped += 1;
            }
            Ok(false) => {
                out.live += 1;
                match fs::read(&path).ok().as_deref().and_then(decode_lease) {
                    Some(PIN_ALL) | None => out.pin_all = true,
                    Some(e) => {
                        if !out.epochs.contains(&e) {
                            out.epochs.push(e);
                        }
                    }
                }
            }
            Err(_) => {
                out.live += 1;
                out.pin_all = true;
            }
        }
    }
    out
}

// ------------------------------------------------- epoch-side copies ----

fn side_dir(store: &Path) -> PathBuf {
    store.join(SIDE_DIR)
}

fn side_file_name(chunk: u32, epoch: u64) -> String {
    format!("side-c{chunk:08}-e{epoch:012}.bin")
}

fn parse_side_name(name: &str) -> Option<(u32, u64)> {
    let rest = name.strip_prefix("side-c")?;
    let rest = rest.strip_suffix(".bin")?;
    let (c, e) = rest.split_once("-e")?;
    Some((c.parse().ok()?, e.parse().ok()?))
}

/// List `(chunk, epoch)` of every epoch-side copy in `store`.
pub fn list_side_copies(store: &Path) -> Vec<(u32, u64)> {
    let mut out = Vec::new();
    let Ok(rd) = fs::read_dir(side_dir(store)) else { return out };
    for entry in rd.flatten() {
        if let Some(name) = entry.file_name().to_str() {
            if let Some(pair) = parse_side_name(name) {
                out.push(pair);
            }
        }
    }
    out
}

/// Path of the side copy for `(chunk, epoch)` (whether or not it exists).
pub fn side_copy_path(store: &Path, chunk: u32, epoch: u64) -> PathBuf {
    side_dir(store).join(side_file_name(chunk, epoch))
}

/// Materialize one chunk's current bytes as the side copy for `epoch`.
/// Reflink from the live backing file when the filesystem supports it;
/// otherwise copy through the mapping. Written tmp+rename so a torn
/// writer never leaves a short file a reader could map. Returns whether
/// the clone path was taken. `overwrite` distinguishes the flusher
/// (whose re-flush of an uncommitted epoch tag must replace the copy)
/// from attaching readers (who must reuse, never clobber, a copy
/// another reader may already map).
pub(crate) fn write_side_copy(
    store: &Path,
    segment: &SegmentStorage,
    chunk: u32,
    chunk_size: usize,
    epoch: u64,
    overwrite: bool,
) -> Result<reflink::CopyMethod> {
    let dir = side_dir(store);
    fs::create_dir_all(&dir).map_err(|e| Error::io(&dir, e))?;
    let dst = dir.join(side_file_name(chunk, epoch));
    if !overwrite && dst.exists() {
        return Ok(reflink::CopyMethod::Fallback);
    }
    let tmp = dir.join(format!("{}.tmp{}", side_file_name(chunk, epoch), std::process::id()));
    faults::check(faults::Site::Create).map_err(|e| Error::io(&tmp, e))?;
    let tf = OpenOptions::new()
        .read(true)
        .write(true)
        .create(true)
        .truncate(true)
        .open(&tmp)
        .map_err(|e| Error::io(&tmp, e))?;
    let offset = chunk as usize * chunk_size;
    let (file_idx, file_off) = segment.locate(offset);
    let method = segment
        .with_file(file_idx, |src| {
            reflink::clone_file_range(src, file_off as u64, chunk_size as u64, &tf, 0)
        })
        .ok_or_else(|| {
            Error::Datastore(format!("side copy: chunk {chunk} has no backing file"))
        })??;
    drop(tf);
    if let Err(e) = faults::check(faults::Site::Rename).and_then(|()| fs::rename(&tmp, &dst)) {
        let _ = fs::remove_file(&tmp);
        return Err(Error::io(&dst, e));
    }
    Ok(method)
}

/// Flusher hook: preserve every chunk in `chunks` as side copies tagged
/// `epoch` (the epoch the in-flight flush will commit), **before** the
/// in-place msync overwrites the live files. Returns
/// `(copies_written, reflinked)`.
pub(crate) fn preserve_chunks(
    store: &Path,
    segment: &SegmentStorage,
    chunks: &[usize],
    chunk_size: usize,
    epoch: u64,
) -> Result<(u64, u64)> {
    let mut copies = 0u64;
    let mut reflinks = 0u64;
    for &c in chunks {
        let m = write_side_copy(store, segment, c as u32, chunk_size, epoch, true)?;
        copies += 1;
        if m == reflink::CopyMethod::Reflink {
            reflinks += 1;
        }
    }
    Ok((copies, reflinks))
}

/// Resolve which side epoch a reader pinned at `pin` should map for
/// `chunk`: the newest copy at or below the pin.
pub(crate) fn resolve_side(sides: &HashMap<u32, Vec<u64>>, chunk: u32, pin: u64) -> Option<u64> {
    sides.get(&chunk)?.iter().copied().filter(|&e| e <= pin).max()
}

/// Index a [`list_side_copies`] listing by chunk (epochs unsorted).
pub(crate) fn index_sides(listing: &[(u32, u64)]) -> HashMap<u32, Vec<u64>> {
    let mut map: HashMap<u32, Vec<u64>> = HashMap::new();
    for &(c, e) in listing {
        map.entry(c).or_default().push(e);
    }
    map
}

/// Prune the epoch-side store: keep, per chunk, every copy that is the
/// newest at or below some protected epoch (committer's current +
/// previous manifests, plus every live pin), and every copy newer than
/// all of them (the flusher's not-yet-committed tag). Callers skip this
/// entirely under pin-all.
pub(crate) fn gc_side_copies(store: &Path, protected: &[u64]) {
    if protected.is_empty() {
        return;
    }
    let listing = list_side_copies(store);
    let sides = index_sides(&listing);
    let max_protected = protected.iter().copied().max().unwrap_or(0);
    for (chunk, epochs) in &sides {
        let keep: Vec<u64> = protected
            .iter()
            .filter_map(|&p| epochs.iter().copied().filter(|&e| e <= p).max())
            .collect();
        for &e in epochs {
            if e > max_protected || keep.contains(&e) {
                continue;
            }
            let _ = fs::remove_file(side_copy_path(store, *chunk, e));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    #[test]
    fn lease_roundtrip_and_scan() {
        let d = TempDir::new("readers-lease");
        let store = d.path().to_path_buf();
        let mut lease = ReaderLease::acquire(&store).unwrap();
        // fresh lease pins everything
        let scan = scan_pins(&store);
        assert_eq!(scan.live, 1);
        assert!(scan.pin_all);
        lease.pin(7).unwrap();
        let scan = scan_pins(&store);
        assert_eq!(scan.live, 1);
        assert!(!scan.pin_all);
        assert_eq!(scan.epochs, vec![7]);
        drop(lease);
        let scan = scan_pins(&store);
        assert_eq!(scan.live, 0);
        assert!(scan.epochs.is_empty());
    }

    #[test]
    fn stale_lease_is_reaped() {
        let d = TempDir::new("readers-stale");
        let store = d.path().to_path_buf();
        // a lease file with no flock holder (simulates a kill-9'd reader)
        let dir = readers_dir(&store);
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("lease-99999-0");
        fs::write(&path, encode_lease(3)).unwrap();
        let scan = scan_pins(&store);
        assert_eq!(scan.reaped, 1);
        assert_eq!(scan.live, 0);
        assert!(!path.exists(), "stale lease reaped");
    }

    #[test]
    fn torn_lease_record_pins_everything() {
        let d = TempDir::new("readers-torn");
        let store = d.path().to_path_buf();
        let mut lease = ReaderLease::acquire(&store).unwrap();
        lease.pin(4).unwrap();
        // corrupt the record behind the lease's back
        fs::write(lease.path(), b"garbage").unwrap();
        let scan = scan_pins(&store);
        assert_eq!(scan.live, 1);
        assert!(scan.pin_all, "unreadable record must fail conservative");
    }

    #[test]
    fn side_name_roundtrip() {
        let name = side_file_name(42, 9000);
        assert_eq!(parse_side_name(&name), Some((42, 9000)));
        assert_eq!(parse_side_name("side-cxx-e1.bin"), None);
        assert_eq!(parse_side_name("manifest-000000000001.bin"), None);
    }

    #[test]
    fn side_resolution_and_gc() {
        let d = TempDir::new("readers-side");
        let store = d.path().to_path_buf();
        let dir = side_dir(&store);
        fs::create_dir_all(&dir).unwrap();
        for (c, e) in [(0u32, 2u64), (0, 5), (0, 9), (1, 5)] {
            fs::write(dir.join(side_file_name(c, e)), b"x").unwrap();
        }
        let sides = index_sides(&list_side_copies(&store));
        assert_eq!(resolve_side(&sides, 0, 7), Some(5));
        assert_eq!(resolve_side(&sides, 0, 9), Some(9));
        assert_eq!(resolve_side(&sides, 0, 1), None);
        assert_eq!(resolve_side(&sides, 1, 5), Some(5));
        // protect epochs {5, 9}: chunk 0 keeps 5 and 9, drops 2;
        // chunk 1 keeps 5
        gc_side_copies(&store, &[9, 5]);
        let mut left = list_side_copies(&store);
        left.sort_unstable();
        assert_eq!(left, vec![(0, 5), (0, 9), (1, 5)]);
    }
}

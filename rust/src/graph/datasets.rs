//! Synthetic stand-ins for the four SNAP graphs of the GBTL case study
//! (paper §7.4): as-733 (AS), email-Eu-core (EE), ego-Facebook (FB) and
//! wiki-Vote (WV). Matched on published |V| and |E| and generated with
//! R-MAT-style skew (DESIGN.md §3: Fig 7/8 only depend on scale and
//! degree structure).

use crate::graph::rmat::RmatGenerator;
use crate::util::bits::log2_ceil;

/// A named small benchmark graph.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: &'static str,
    pub long_name: &'static str,
    pub n: usize,
    pub edges: Vec<(u64, u64)>,
}

/// Published sizes of the SNAP graphs used in §7.4.
pub const SNAP_SIZES: [(&str, &str, usize, usize); 4] = [
    ("AS", "as-733", 6_474, 13_895),
    ("EE", "email-Eu-core", 1_005, 25_571),
    ("FB", "ego-Facebook", 4_039, 88_234),
    ("WV", "wiki-Vote", 7_115, 103_689),
];

/// Generate the synthetic stand-in for `short_name` ("AS" | "EE" | "FB"
/// | "WV").
pub fn load(short_name: &str) -> Option<Dataset> {
    let (name, long_name, n, m) =
        *SNAP_SIZES.iter().find(|(s, ..)| *s == short_name)?;
    // R-MAT on the next power of two, relabelled into [0, n) — keeps the
    // heavy tail while hitting the exact vertex count.
    let scale = log2_ceil(n as u64);
    let ef = m.div_ceil(1usize << scale).max(1);
    let gen = RmatGenerator::graph500(scale, ef).seed(0xDA7A ^ n as u64);
    let mut edges: Vec<(u64, u64)> = gen
        .generate()
        .into_iter()
        .map(|(s, d)| (s % n as u64, d % n as u64))
        .filter(|(s, d)| s != d)
        .take(m)
        .collect();
    // Ensure every vertex id < n appears at most... (range is enforced
    // by the modulo above; self-loops removed as SNAP graphs are simple.)
    edges.dedup();
    Some(Dataset { name, long_name, n, edges })
}

/// All four datasets, in the paper's presentation order.
pub fn all() -> Vec<Dataset> {
    SNAP_SIZES.iter().map(|(s, ..)| load(s).unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_match_published_scale() {
        for (short, _, n, m) in SNAP_SIZES {
            let d = load(short).unwrap();
            assert_eq!(d.n, n);
            // within 20% of the published edge count (dedup/self-loop
            // filtering trims a little)
            assert!(
                (d.edges.len() as f64) > 0.8 * m as f64,
                "{short}: {} vs {m}",
                d.edges.len()
            );
            for &(s, dd) in &d.edges {
                assert!((s as usize) < n && (dd as usize) < n);
                assert_ne!(s, dd, "no self loops");
            }
        }
    }

    #[test]
    fn unknown_name() {
        assert!(load("LIVEJOURNAL").is_none());
    }

    #[test]
    fn all_returns_four() {
        assert_eq!(all().len(), 4);
    }
}

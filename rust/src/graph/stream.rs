//! Timestamped edge streams for the incremental-construction experiments
//! (paper §6.4): the Wikipedia page-reference graph and the Reddit
//! author–author graph, sorted by timestamp and partitioned by month.
//!
//! The real dumps (1.8B / 4.4B edges) are not available on this testbed;
//! per DESIGN.md §3 we generate synthetic streams preserving the three
//! properties the benchmark exercises: (1) arrival in monthly chunks with
//! *growing* volume, (2) heavy-tailed degree distribution (preferential
//! attachment), (3) a growing vertex set so later months touch both old
//! and new regions of the datastore (sparse updates).

use crate::util::rng::Xoshiro256ss;

/// One calendar month of edges.
#[derive(Clone, Debug)]
pub struct MonthBatch {
    pub month: u32,
    pub edges: Vec<(u64, u64)>,
}

/// Stream generator configuration.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    pub months: u32,
    /// Edges in the first month.
    pub first_month_edges: usize,
    /// Per-month multiplicative growth (Wikipedia grew superlinearly).
    pub growth: f64,
    /// Probability that an endpoint is an *existing* heavy vertex
    /// (preferential attachment strength).
    pub pref_attach: f64,
    /// New vertices are drawn per month as `edges_this_month / vertex_ratio`.
    pub vertex_ratio: usize,
    pub seed: u64,
}

impl StreamConfig {
    /// Wikipedia-like page-reference growth: strong growth, strong hubs
    /// (category/portal pages).
    pub fn wiki_like(months: u32, first_month_edges: usize) -> Self {
        Self {
            months,
            first_month_edges,
            growth: 1.25,
            pref_attach: 0.70,
            vertex_ratio: 8,
            seed: 20170701,
        }
    }

    /// Reddit-like author–author comments: denser (more edges per
    /// vertex), slightly weaker hubs, faster growth.
    pub fn reddit_like(months: u32, first_month_edges: usize) -> Self {
        Self {
            months,
            first_month_edges,
            growth: 1.35,
            pref_attach: 0.55,
            vertex_ratio: 16,
            seed: 20051223,
        }
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Total edges across all months.
    pub fn total_edges(&self) -> usize {
        let mut total = 0usize;
        let mut m = self.first_month_edges as f64;
        for _ in 0..self.months {
            total += m as usize;
            m *= self.growth;
        }
        total
    }

    /// Generate the full stream. Deterministic in `seed`.
    pub fn generate(&self) -> Vec<MonthBatch> {
        let mut rng = Xoshiro256ss::new(self.seed);
        let mut batches = Vec::with_capacity(self.months as usize);
        // endpoint pool for preferential attachment: sampling uniformly
        // from *edge endpoints seen so far* is exactly
        // degree-proportional sampling.
        let mut pool: Vec<u64> = Vec::new();
        let mut nverts: u64 = 2;
        let mut month_edges = self.first_month_edges as f64;
        for month in 0..self.months {
            let m = month_edges as usize;
            let mut edges = Vec::with_capacity(m);
            // grow the vertex set
            nverts += (m / self.vertex_ratio).max(1) as u64;
            for _ in 0..m {
                let src = if !pool.is_empty() && rng.next_f64() < self.pref_attach {
                    pool[rng.gen_range(pool.len() as u64) as usize]
                } else {
                    rng.gen_range(nverts)
                };
                let dst = if !pool.is_empty() && rng.next_f64() < self.pref_attach {
                    pool[rng.gen_range(pool.len() as u64) as usize]
                } else {
                    rng.gen_range(nverts)
                };
                // keep the pool bounded: reservoir-ish subsampling
                if pool.len() < 1_000_000 {
                    pool.push(src);
                    pool.push(dst);
                } else {
                    let i = rng.gen_range(pool.len() as u64) as usize;
                    pool[i] = src;
                }
                edges.push((src, dst));
            }
            batches.push(MonthBatch { month, edges });
            month_edges *= self.growth;
        }
        batches
    }

    /// Upper bound on vertex ids produced by [`Self::generate`].
    pub fn max_vertices(&self) -> u64 {
        let mut nverts: u64 = 2;
        let mut month_edges = self.first_month_edges as f64;
        for _ in 0..self.months {
            nverts += ((month_edges as usize) / self.vertex_ratio).max(1) as u64;
            month_edges *= self.growth;
        }
        nverts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monthly_growth() {
        let cfg = StreamConfig::wiki_like(6, 1000);
        let batches = cfg.generate();
        assert_eq!(batches.len(), 6);
        for w in batches.windows(2) {
            assert!(
                w[1].edges.len() > w[0].edges.len(),
                "months must grow: {} -> {}",
                w[0].edges.len(),
                w[1].edges.len()
            );
        }
        let total: usize = batches.iter().map(|b| b.edges.len()).sum();
        assert_eq!(total, cfg.total_edges());
    }

    #[test]
    fn deterministic() {
        let a = StreamConfig::reddit_like(4, 500).generate();
        let b = StreamConfig::reddit_like(4, 500).generate();
        assert_eq!(a[3].edges, b[3].edges);
    }

    #[test]
    fn vertex_ids_in_bound() {
        let cfg = StreamConfig::wiki_like(5, 800);
        let max_v = cfg.max_vertices();
        for b in cfg.generate() {
            for (s, d) in b.edges {
                assert!(s < max_v && d < max_v);
            }
        }
    }

    #[test]
    fn heavy_tail_exists() {
        let cfg = StreamConfig::wiki_like(8, 2000);
        let batches = cfg.generate();
        let mut deg = std::collections::HashMap::<u64, u32>::new();
        for b in &batches {
            for &(s, _) in &b.edges {
                *deg.entry(s).or_default() += 1;
            }
        }
        let total: u32 = deg.values().sum();
        let mean = total as f64 / deg.len() as f64;
        let max = *deg.values().max().unwrap() as f64;
        assert!(max > 10.0 * mean, "hubs expected: max {max} mean {mean}");
    }
}

//! Fragment-ELL graph representation — the interchange format between the
//! L3 coordinator and the AOT-compiled L2 analytics (see
//! `python/compile/model.py` for the semantics).
//!
//! A graph of `n` vertices becomes `F` fragments of width `w`; fragment
//! `f` holds up to `w` **in-neighbors** of vertex `owner[f]` (pull-style
//! analytics). High-degree vertices span multiple fragments.

use crate::util::div_ceil;

/// Fragment-ELL form of a directed graph, plus the per-vertex PageRank
/// side vectors.
#[derive(Clone, Debug)]
pub struct EllGraph {
    pub n: usize,
    pub w: usize,
    /// Fragment count (rows of `idx`/`val`).
    pub f: usize,
    /// `f * w` in-neighbor ids, row major.
    pub idx: Vec<i32>,
    /// `f * w` validity mask (1.0 edge, 0.0 padding).
    pub val: Vec<f32>,
    /// Owning vertex of each fragment.
    pub owner: Vec<i32>,
    /// 1/outdeg per vertex (0 for dangling).
    pub inv_outdeg: Vec<f32>,
    /// 1.0 where outdeg == 0.
    pub dangling: Vec<f32>,
}

impl EllGraph {
    /// Build from a directed edge list. `w` is the ELL width (must match
    /// the AOT ladder's `ELL_W`, 32, when executed through PJRT).
    pub fn from_edges(n: usize, edges: &[(u64, u64)], w: usize) -> Self {
        let mut in_nbrs: Vec<Vec<i32>> = vec![Vec::new(); n];
        let mut outdeg = vec![0u64; n];
        for &(s, d) in edges {
            let (s, d) = (s as usize, d as usize);
            assert!(s < n && d < n, "edge ({s},{d}) outside vertex range {n}");
            in_nbrs[d].push(s as i32);
            outdeg[s] += 1;
        }
        let mut idx = Vec::new();
        let mut val = Vec::new();
        let mut owner = Vec::new();
        for (v, nbrs) in in_nbrs.iter().enumerate() {
            let nfrag = div_ceil(nbrs.len().max(1), w).max(1);
            for c in 0..nfrag {
                let chunk = &nbrs[(c * w).min(nbrs.len())..((c + 1) * w).min(nbrs.len())];
                let mut row_i = vec![0i32; w];
                let mut row_v = vec![0f32; w];
                row_i[..chunk.len()].copy_from_slice(chunk);
                for rv in row_v.iter_mut().take(chunk.len()) {
                    *rv = 1.0;
                }
                idx.extend_from_slice(&row_i);
                val.extend_from_slice(&row_v);
                owner.push(v as i32);
            }
        }
        let f = owner.len();
        let inv_outdeg =
            outdeg.iter().map(|&d| if d > 0 { 1.0 / d as f32 } else { 0.0 }).collect();
        let dangling = outdeg.iter().map(|&d| if d == 0 { 1.0 } else { 0.0 }).collect();
        Self { n, w, f, idx, val, owner, inv_outdeg, dangling }
    }

    /// Number of real (non-padding) edge slots.
    pub fn nnz(&self) -> usize {
        self.val.iter().filter(|&&v| v > 0.0).count()
    }

    /// Pad to `(n_pad, f_pad)` for a compiled shape variant. Padded
    /// vertices are isolated (inv_outdeg = dangling = 0) and padded
    /// fragments owned by vertex 0 with zero mask — exactness argument in
    /// `model.pagerank_step`'s docstring.
    pub fn padded(&self, n_pad: usize, f_pad: usize) -> EllGraph {
        assert!(n_pad >= self.n && f_pad >= self.f, "variant too small");
        let mut g = self.clone();
        g.idx.resize(f_pad * self.w, 0);
        g.val.resize(f_pad * self.w, 0.0);
        g.owner.resize(f_pad, 0);
        g.inv_outdeg.resize(n_pad, 0.0);
        g.dangling.resize(n_pad, 0.0);
        g.n = n_pad;
        g.f = f_pad;
        g
    }

    /// Native (pure-rust) PageRank power iteration — the oracle the PJRT
    /// path is tested against, and the non-PJRT fallback.
    pub fn pagerank_native(&self, alpha: f32, iters: usize) -> Vec<f32> {
        let n = self.n;
        let mut ranks = vec![1.0 / n as f32; n];
        let mut next = vec![0f32; n];
        for _ in 0..iters {
            let mut dmass = 0f64;
            for v in 0..n {
                dmass += (ranks[v] * self.dangling[v]) as f64;
            }
            for x in next.iter_mut() {
                *x = 0.0;
            }
            for frag in 0..self.f {
                let o = self.owner[frag] as usize;
                let mut acc = 0f32;
                for k in 0..self.w {
                    let j = self.idx[frag * self.w + k] as usize;
                    acc += ranks[j]
                        * self.inv_outdeg[j]
                        * self.val[frag * self.w + k];
                }
                next[o] += acc;
            }
            for v in 0..n {
                ranks[v] = (1.0 - alpha) / n as f32
                    + alpha * next[v]
                    + (dmass as f32) * alpha / n as f32;
            }
        }
        ranks
    }

    /// Native BFS levels from `source` (-1 = unreachable). Follows the
    /// *out*-edges (this ELL stores in-neighbors, so we scan fragments).
    pub fn bfs_native(&self, source: usize) -> Vec<i64> {
        let mut level = vec![-1i64; self.n];
        level[source] = 0;
        let mut frontier = vec![source];
        let mut lvl = 0i64;
        while !frontier.is_empty() {
            lvl += 1;
            let in_frontier: std::collections::HashSet<i32> =
                frontier.iter().map(|&v| v as i32).collect();
            let mut next = Vec::new();
            for frag in 0..self.f {
                let o = self.owner[frag] as usize;
                if level[o] >= 0 {
                    continue;
                }
                let hit = (0..self.w).any(|k| {
                    self.val[frag * self.w + k] > 0.0
                        && in_frontier.contains(&self.idx[frag * self.w + k])
                });
                if hit {
                    level[o] = lvl;
                    next.push(o);
                }
            }
            next.sort_unstable();
            next.dedup();
            frontier = next;
        }
        level
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> EllGraph {
        // 0 -> 1, 0 -> 2, 1 -> 3, 2 -> 3
        EllGraph::from_edges(4, &[(0, 1), (0, 2), (1, 3), (2, 3)], 4)
    }

    #[test]
    fn from_edges_shapes() {
        let g = diamond();
        assert_eq!(g.n, 4);
        assert_eq!(g.f, 4); // one fragment per vertex here
        assert_eq!(g.nnz(), 4);
        assert_eq!(g.inv_outdeg[0], 0.5);
        assert_eq!(g.dangling[3], 1.0);
        assert_eq!(g.dangling[0], 0.0);
    }

    #[test]
    fn high_degree_vertex_splits_fragments() {
        let n = 40;
        let edges: Vec<(u64, u64)> = (1..n as u64).map(|s| (s, 0)).collect();
        let g = EllGraph::from_edges(n, &edges, 8);
        // vertex 0 has 39 in-neighbors -> ceil(39/8) = 5 fragments
        let frags0 = g.owner.iter().filter(|&&o| o == 0).count();
        assert_eq!(frags0, 5);
        assert_eq!(g.nnz(), 39);
    }

    #[test]
    fn padding_preserves_pagerank() {
        let g = diamond();
        let gp = g.padded(16, 8);
        let r1 = g.pagerank_native(0.85, 50);
        let r2 = gp.pagerank_native(0.85, 50);
        // padded run distributes teleport over 16 vertices, so compare
        // only the *shape-preserving* property we rely on at the engine
        // level: engine feeds base/dweight vectors; the native padded
        // run here uses n_pad so ranks differ. Instead check structure:
        assert_eq!(gp.f, 8);
        assert_eq!(gp.n, 16);
        assert_eq!(gp.nnz(), g.nnz());
        assert_eq!(r1.len(), 4);
        assert_eq!(r2.len(), 16);
    }

    #[test]
    fn pagerank_native_sums_to_one() {
        let g = diamond();
        let r = g.pagerank_native(0.85, 100);
        let s: f32 = r.iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "sum = {s}");
        // symmetric vertices 1 and 2 must tie; 3 collects the most
        assert!((r[1] - r[2]).abs() < 1e-6);
        assert!(r[3] > r[1] && r[1] > r[0]);
    }

    #[test]
    fn bfs_native_levels() {
        let g = diamond();
        assert_eq!(g.bfs_native(0), vec![0, 1, 1, 2]);
        assert_eq!(g.bfs_native(3), vec![-1, -1, -1, 0]);
    }
}

//! R-MAT synthetic graph generator (Chakrabarti, Zhan, Faloutsos 2004)
//! with the Graph500 parameterization the paper uses (§6.3.2):
//! a SCALE `s` graph has 2^s vertices and 2^s × edge_factor (16)
//! undirected edges, and vertex IDs are *scrambled* "to remove unexpected
//! localities".

use crate::util::rng::Xoshiro256ss;

/// Graph500 R-MAT probabilities.
pub const G500_A: f64 = 0.57;
pub const G500_B: f64 = 0.19;
pub const G500_C: f64 = 0.19;
pub const G500_D: f64 = 0.05;

/// Configurable R-MAT generator.
#[derive(Clone, Debug)]
pub struct RmatGenerator {
    pub scale: u32,
    pub edge_factor: usize,
    pub a: f64,
    pub b: f64,
    pub c: f64,
    pub seed: u64,
    pub scramble: bool,
}

impl RmatGenerator {
    /// Graph500 settings: 2^scale vertices, edge_factor·2^scale generated
    /// (undirected) edge tuples.
    pub fn graph500(scale: u32, edge_factor: usize) -> Self {
        Self {
            scale,
            edge_factor,
            a: G500_A,
            b: G500_B,
            c: G500_C,
            seed: 0,
            scramble: true,
        }
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn num_vertices(&self) -> u64 {
        1u64 << self.scale
    }

    pub fn num_edges(&self) -> u64 {
        self.num_vertices() * self.edge_factor as u64
    }

    /// Bijective scramble of vertex ids within [0, 2^scale): alternating
    /// odd-multiply and xorshift rounds — both bijective on the
    /// power-of-two domain (the Graph500 spirit without its exact LCG).
    #[inline]
    pub fn scramble_id(&self, v: u64) -> u64 {
        if !self.scramble {
            return v;
        }
        let mask = self.num_vertices() - 1;
        let mut x = v;
        // seed-derived odd multipliers
        let m1 = (self.seed | 1).wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
        let m2 = (self.seed | 1).wrapping_mul(0xBF58_476D_1CE4_E5B9) | 1;
        x = x.wrapping_mul(m1) & mask;
        x ^= x >> (self.scale / 2).max(1);
        x = x.wrapping_mul(m2) & mask;
        x ^= x >> (self.scale / 2).max(1);
        x & mask
    }

    /// Sample one directed edge tuple.
    #[inline]
    fn sample(&self, rng: &mut Xoshiro256ss) -> (u64, u64) {
        let mut src = 0u64;
        let mut dst = 0u64;
        for _ in 0..self.scale {
            let r = rng.next_f64();
            let (sbit, dbit) = if r < self.a {
                (0, 0)
            } else if r < self.a + self.b {
                (0, 1)
            } else if r < self.a + self.b + self.c {
                (1, 0)
            } else {
                (1, 1)
            };
            src = (src << 1) | sbit;
            dst = (dst << 1) | dbit;
        }
        (self.scramble_id(src), self.scramble_id(dst))
    }

    /// Generate the full edge list (directed tuples; the paper inserts
    /// each generated edge in both directions for undirected semantics —
    /// that duplication happens at the benchmark layer).
    pub fn generate(&self) -> Vec<(u64, u64)> {
        let mut rng = Xoshiro256ss::new(self.seed ^ 0xD6E8_FEB8_6659_FD93);
        let m = self.num_edges() as usize;
        let mut edges = Vec::with_capacity(m);
        for _ in 0..m {
            edges.push(self.sample(&mut rng));
        }
        edges
    }

    /// Generate in chunks (the dynamic-construction benchmark generates a
    /// chunk into DRAM, then inserts it — §6.3.2 — so generation cost can
    /// be excluded from timings).
    pub fn generate_chunks(&self, chunk: usize) -> Vec<Vec<(u64, u64)>> {
        let mut rng = Xoshiro256ss::new(self.seed ^ 0xD6E8_FEB8_6659_FD93);
        let mut left = self.num_edges() as usize;
        let mut out = Vec::new();
        while left > 0 {
            let k = chunk.min(left);
            let mut c = Vec::with_capacity(k);
            for _ in 0..k {
                c.push(self.sample(&mut rng));
            }
            out.push(c);
            left -= k;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn counts_and_range() {
        let g = RmatGenerator::graph500(8, 16).seed(3);
        let edges = g.generate();
        assert_eq!(edges.len(), 256 * 16);
        for &(s, d) in &edges {
            assert!(s < 256 && d < 256);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = RmatGenerator::graph500(7, 8).seed(5).generate();
        let b = RmatGenerator::graph500(7, 8).seed(5).generate();
        let c = RmatGenerator::graph500(7, 8).seed(6).generate();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn scramble_is_bijective() {
        let g = RmatGenerator::graph500(10, 1).seed(9);
        let set: HashSet<u64> = (0..1024u64).map(|v| g.scramble_id(v)).collect();
        assert_eq!(set.len(), 1024);
    }

    #[test]
    fn skewed_degree_distribution() {
        // R-MAT must be much more skewed than Erdős–Rényi: the max
        // degree should far exceed the mean.
        let g = RmatGenerator::graph500(12, 16).seed(1);
        let edges = g.generate();
        let mut deg = vec![0u32; 4096];
        for &(s, _) in &edges {
            deg[s as usize] += 1;
        }
        let mean = edges.len() as f64 / 4096.0;
        let max = *deg.iter().max().unwrap() as f64;
        assert!(
            max > mean * 8.0,
            "expected heavy tail: max {max}, mean {mean}"
        );
    }

    #[test]
    fn chunked_equals_monolithic() {
        let g = RmatGenerator::graph500(7, 4).seed(2);
        let whole = g.generate();
        let chunks = g.generate_chunks(100);
        let glued: Vec<_> = chunks.into_iter().flatten().collect();
        assert_eq!(whole, glued);
    }
}

//! Persistent ELL cache: store the analytics-ready fragment-ELL arrays
//! *inside* the Metall datastore so that the reattach→analyze path skips
//! the adjacency-list→ELL conversion entirely — the "ingest once,
//! analyze many" workflow of paper §7 applied to the PJRT engine's input
//! format.
//!
//! The cache records the (num_edges, nbanks) fingerprint of the source
//! graph; `load` returns `None` when the graph has changed since the
//! cache was built (e.g. another month was ingested), in which case the
//! caller rebuilds with [`EllCache::build`].

use crate::alloc::manager::Persist;
use crate::alloc::SegmentAlloc;
use crate::containers::{BankedAdjacency, PVec};
use crate::error::Result;
use crate::graph::ell::EllGraph;

/// Persistent handle (nest under a named root).
#[derive(Clone, Copy, Debug)]
#[repr(C)]
pub struct EllCache {
    n: u64,
    w: u64,
    f: u64,
    /// Fingerprint of the source graph at build time.
    src_edges: u64,
    idx: PVec<i32>,
    val: PVec<f32>,
    owner: PVec<i32>,
    inv_outdeg: PVec<f32>,
    dangling: PVec<f32>,
}

unsafe impl Persist for EllCache {}

/// The name under which the CLI stores the cache.
pub const CACHE_NAME: &str = "__ell_cache";

impl EllCache {
    /// Convert `graph` to ELL and persist the arrays via `a`.
    pub fn build<A: SegmentAlloc>(
        a: &A,
        graph: &BankedAdjacency,
        w: usize,
    ) -> Result<Self> {
        let edges = graph.to_edge_list(a);
        let n = edges.iter().map(|&(s, d)| s.max(d) + 1).max().unwrap_or(1) as usize;
        let g = EllGraph::from_edges(n, &edges, w);
        let cache = Self {
            n: g.n as u64,
            w: g.w as u64,
            f: g.f as u64,
            src_edges: graph.num_edges(a),
            idx: PVec::create(a)?,
            val: PVec::create(a)?,
            owner: PVec::create(a)?,
            inv_outdeg: PVec::create(a)?,
            dangling: PVec::create(a)?,
        };
        cache.idx.extend_from_slice(a, &g.idx)?;
        cache.val.extend_from_slice(a, &g.val)?;
        cache.owner.extend_from_slice(a, &g.owner)?;
        cache.inv_outdeg.extend_from_slice(a, &g.inv_outdeg)?;
        cache.dangling.extend_from_slice(a, &g.dangling)?;
        Ok(cache)
    }

    /// Materialize back into an [`EllGraph`] **iff** the cache still
    /// matches the graph's current fingerprint.
    pub fn load<A: SegmentAlloc>(
        &self,
        a: &A,
        graph: &BankedAdjacency,
    ) -> Option<EllGraph> {
        if self.src_edges != graph.num_edges(a) {
            return None; // stale: graph grew since the cache was built
        }
        Some(self.load_unchecked(a))
    }

    /// Materialize without the staleness check (snapshots, tools).
    pub fn load_unchecked<A: SegmentAlloc>(&self, a: &A) -> EllGraph {
        EllGraph {
            n: self.n as usize,
            w: self.w as usize,
            f: self.f as usize,
            idx: self.idx.to_vec(a),
            val: self.val.to_vec(a),
            owner: self.owner.to_vec(a),
            inv_outdeg: self.inv_outdeg.to_vec(a),
            dangling: self.dangling.to_vec(a),
        }
    }

    /// Free all cached arrays.
    pub fn destroy<A: SegmentAlloc>(self, a: &A) -> Result<()> {
        self.idx.destroy(a)?;
        self.val.destroy(a)?;
        self.owner.destroy(a)?;
        self.inv_outdeg.destroy(a)?;
        self.dangling.destroy(a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::{ManagerOptions, MetallManager};
    use crate::util::tmp::TempDir;

    fn store_with_graph(dir: &std::path::Path) -> (MetallManager, BankedAdjacency) {
        let m = MetallManager::create_with(dir, ManagerOptions::small_for_tests()).unwrap();
        let g = BankedAdjacency::create(&m, 16).unwrap();
        for s in 0..40u64 {
            for k in 0..(s % 4) {
                g.insert_edge(&m, s, (s + k + 1) % 40).unwrap();
            }
        }
        (m, g)
    }

    #[test]
    fn cache_roundtrips_ell_exactly() {
        let d = TempDir::new("ellc1");
        let (m, g) = store_with_graph(&d.join("s"));
        let cache = EllCache::build(&m, &g, 8).unwrap();
        let direct = {
            let edges = g.to_edge_list(&m);
            let n = edges.iter().map(|&(s, dd)| s.max(dd) + 1).max().unwrap() as usize;
            EllGraph::from_edges(n, &edges, 8)
        };
        let loaded = cache.load(&m, &g).expect("fresh cache must load");
        assert_eq!(loaded.n, direct.n);
        assert_eq!(loaded.f, direct.f);
        assert_eq!(loaded.idx, direct.idx);
        assert_eq!(loaded.val, direct.val);
        assert_eq!(loaded.owner, direct.owner);
        assert_eq!(loaded.inv_outdeg, direct.inv_outdeg);
        assert_eq!(loaded.dangling, direct.dangling);
        m.close().unwrap();
    }

    #[test]
    fn staleness_detection() {
        let d = TempDir::new("ellc2");
        let (m, g) = store_with_graph(&d.join("s"));
        let cache = EllCache::build(&m, &g, 8).unwrap();
        assert!(cache.load(&m, &g).is_some());
        g.insert_edge(&m, 0, 1).unwrap(); // graph grows
        assert!(cache.load(&m, &g).is_none(), "stale cache must be rejected");
        m.close().unwrap();
    }

    #[test]
    fn cache_persists_across_reattach() {
        let d = TempDir::new("ellc3");
        let store = d.join("s");
        let native;
        {
            let (m, g) = store_with_graph(&store);
            let cache = EllCache::build(&m, &g, 8).unwrap();
            native = cache.load(&m, &g).unwrap().pagerank_native(0.85, 20);
            m.construct::<EllCache>(CACHE_NAME, cache).unwrap();
            m.construct::<u64>("graph", g.offset()).unwrap();
            m.close().unwrap();
        }
        let m = MetallManager::open_read_only(&store).unwrap();
        let g = BankedAdjacency::open(&m, m.read(m.find::<u64>("graph").unwrap().unwrap()));
        let cache: EllCache = m.read(m.find::<EllCache>(CACHE_NAME).unwrap().unwrap());
        let ell = cache.load(&m, &g).expect("cache valid after reattach");
        assert_eq!(ell.pagerank_native(0.85, 20), native);
    }
}

//! Graph substrate: generators, streams, and analytic representations.

pub mod ell;
pub mod ell_cache;
pub mod rmat;
pub mod stream;
pub mod datasets;

/// The murmur3 fmix32 bank hash — bit-identical to the L1
/// `kernels/bucket.py` Pallas kernel (tests assert equality through the
/// PJRT runtime).
#[inline]
pub fn bucket_hash32(src: u32, nbanks: u32) -> u32 {
    debug_assert!(nbanks.is_power_of_two());
    let mut h = src;
    h ^= h >> 16;
    h = h.wrapping_mul(0x85EB_CA6B);
    h ^= h >> 13;
    h = h.wrapping_mul(0xC2B2_AE35);
    h ^= h >> 16;
    h & (nbanks - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_hash_reference_values() {
        assert_eq!(bucket_hash32(0, 1024), 0);
        assert!(bucket_hash32(1, 1024) < 1024);
        // spread: sequential ids should not collapse into few banks
        let mut counts = vec![0u32; 64];
        for i in 0..4096u32 {
            counts[bucket_hash32(i, 64) as usize] += 1;
        }
        let max = *counts.iter().max().unwrap();
        assert!(max < 150, "lumpy distribution: max bucket {max}");
    }
}

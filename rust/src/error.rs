//! Crate-wide error type.

use std::path::PathBuf;

/// Unified error type for metall-rs.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Underlying I/O failure (file creation, ftruncate, read/write...).
    #[error("io error at {path:?}: {source}")]
    Io {
        path: PathBuf,
        #[source]
        source: std::io::Error,
    },

    /// Raw system-call failure (mmap, msync, madvise, ioctl...).
    #[error("{call} failed: {source}")]
    Sys {
        call: &'static str,
        #[source]
        source: std::io::Error,
    },

    /// Datastore-level problems: missing, corrupt, version mismatch,
    /// unclean shutdown detected on open.
    #[error("datastore error: {0}")]
    Datastore(String),

    /// Allocation failure: out of segment space, invalid size, etc.
    #[error("allocation error: {0}")]
    Alloc(String),

    /// Named-object errors (construct/find/destroy).
    #[error("named object error: {0}")]
    Name(String),

    /// Requested operation is invalid in the current mode
    /// (e.g. writes on a read-only datastore).
    #[error("invalid operation: {0}")]
    InvalidOp(String),

    /// Background sync engine failures: the flusher thread died, the
    /// engine was shut down with work outstanding, or a background flush
    /// epoch could not be committed.
    #[error("background sync error: {0}")]
    BgSync(String),

    /// The manager was **wounded** by a permanent backend failure and
    /// flipped to degraded read-only mode: committed data keeps
    /// serving, every mutating API returns this, and `close()` refuses
    /// the CLEAN marker so the next open replays recovery from the
    /// last committed manifest. The payload is the originating
    /// failure. See the "Error taxonomy & degraded mode" notes in
    /// [`crate::alloc`] and [`crate::storage`].
    #[error("datastore degraded (read-only after backend failure): {0}")]
    Degraded(String),

    /// PJRT / XLA runtime errors.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Artifact manifest / HLO loading errors.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Configuration / CLI errors.
    #[error("config error: {0}")]
    Config(String),
}

impl Error {
    /// Wrap an `io::Error` with the path it concerns.
    pub fn io(path: impl Into<PathBuf>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }

    /// Capture `errno` after a failed libc call.
    pub fn sys(call: &'static str) -> Self {
        Error::Sys { call, source: std::io::Error::last_os_error() }
    }
}

pub type Result<T> = std::result::Result<T, Error>;

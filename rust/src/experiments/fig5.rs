//! Fig 5 / Fig 6 — incremental graph construction on network file
//! systems (paper §6.4): monthly chunks of a timestamped edge stream are
//! appended to a persistent graph; each iteration opens the datastore,
//! ingests, flushes, and closes. Three I/O configurations ×
//! two simulated file systems (DESIGN.md §3: Lustre/VAST are modeled by
//! the [`SimNetFs`] cost account; all data also physically lands on
//! local disk for full functional fidelity).

use std::path::Path;

use crate::alloc::{ManagerOptions, MetallManager};
use crate::containers::BankedAdjacency;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pipeline::{ingest, PipelineConfig};
use crate::error::Result;
use crate::graph::stream::{MonthBatch, StreamConfig};
use crate::storage::mmap::page_size;
use crate::storage::netfs::{profile_by_name_strict, SimNetFs};
use crate::telemetry::{histogram::HistogramSnapshot, Op};

/// The three §6.4.2 configurations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoMode {
    /// Standard shared mapping straight "on" the network FS: the kernel
    /// writes back sparse dirty pages page-by-page (charged per page).
    DirectMmap,
    /// Stage the whole datastore to tmpfs-like local memory, work
    /// locally, stage back (charged per file + bulk bytes).
    StagingMmap,
    /// bs-mmap: private mapping + user msync with run coalescing and
    /// parallel per-file write-back (charged per run).
    BsMmap,
}

impl IoMode {
    pub fn name(self) -> &'static str {
        match self {
            IoMode::DirectMmap => "direct-mmap",
            IoMode::StagingMmap => "staging-mmap",
            IoMode::BsMmap => "bs-mmap",
        }
    }

    pub fn all() -> [IoMode; 3] {
        [IoMode::DirectMmap, IoMode::StagingMmap, IoMode::BsMmap]
    }
}

#[derive(Clone, Debug)]
pub struct Fig5Params {
    pub months: u32,
    pub first_month_edges: usize,
    pub nbanks: usize,
    pub chunk_size: usize,
    pub file_size: usize,
}

impl Default for Fig5Params {
    fn default() -> Self {
        Self {
            months: 8,
            first_month_edges: 20_000,
            nbanks: 256,
            chunk_size: 256 << 10,
            file_size: 4 << 20,
        }
    }
}

/// Per-iteration result (one month).
#[derive(Clone, Debug)]
pub struct MonthRow {
    pub fs: String,
    pub dataset: String,
    pub mode: &'static str,
    pub month: u32,
    pub edges: u64,
    /// Local compute/ingest seconds + simulated network ingest charge.
    pub ingest_secs: f64,
    /// Flush (write-back / stage-out) seconds incl. simulated charge.
    pub flush_secs: f64,
}

fn manager_opts(p: &Fig5Params, mode: IoMode) -> ManagerOptions {
    ManagerOptions {
        chunk_size: p.chunk_size,
        file_size: p.file_size,
        vm_reserve: 16 << 30,
        private_mode: mode == IoMode::BsMmap,
        populate: mode == IoMode::BsMmap, // §6.4.2: MAP_POPULATE read-ahead
        // §6.4.2: file-space freeing disabled for cross-FS comparability
        free_file_space: false,
        parallel_sync: true,
        shards: 0,      // auto
        topology: None, // machine topology
        // foreground sync per month boundary (fig5 measures the flush
        // explicitly); background triggers stay at their defaults (off)
        ..Default::default()
    }
}

fn datastore_bytes(dir: &Path) -> u64 {
    fn walk(d: &Path, acc: &mut u64) {
        if let Ok(rd) = std::fs::read_dir(d) {
            for e in rd.flatten() {
                let p = e.path();
                if p.is_dir() {
                    walk(&p, acc);
                } else if let Ok(md) = e.metadata() {
                    *acc += md.len();
                }
            }
        }
    }
    let mut total = 0;
    walk(dir, &mut total);
    total
}

fn count_files(dir: &Path) -> u64 {
    std::fs::read_dir(dir.join("segment")).map(|rd| rd.count() as u64).unwrap_or(0) + 3
}

/// Run one (fs, dataset, mode) cell; returns a row per month.
pub fn run_cell(
    fs_name: &str,
    dataset: &str,
    mode: IoMode,
    p: &Fig5Params,
    workdir: &Path,
) -> Result<Vec<MonthRow>> {
    let profile = profile_by_name_strict(fs_name)?;
    let net = SimNetFs::new(profile);
    let stream = match dataset {
        "wiki" => StreamConfig::wiki_like(p.months, p.first_month_edges),
        _ => StreamConfig::reddit_like(p.months, p.first_month_edges),
    };
    let batches: Vec<MonthBatch> = stream.generate();
    let dir = workdir.join(format!("fig5-{fs_name}-{dataset}-{}", mode.name()));
    let _ = std::fs::remove_dir_all(&dir);
    let ps = page_size() as u64;

    let mut rows = Vec::new();
    for (i, b) in batches.iter().enumerate() {
        let first = i == 0;
        let t0 = std::time::Instant::now();
        let mut ingest_sim = 0.0;
        let flush_sim;

        // --- open (metadata charges against the network FS) ---
        if mode == IoMode::StagingMmap && !first {
            // stage in: bulk copy the whole datastore from the net FS
            let bytes = datastore_bytes(&dir);
            ingest_sim += net.charge_io(count_files(&dir), bytes, profile.concurrency);
        } else if !first {
            ingest_sim += net.charge_metadata(count_files(&dir));
        }
        let mgr = if first {
            MetallManager::create_with(&dir, manager_opts(p, mode))?
        } else {
            MetallManager::open_with(&dir, manager_opts(p, mode), false, false)?
        };
        let graph = match mgr.find::<u64>("graph")? {
            Some(off) => BankedAdjacency::open(&mgr, mgr.read(off)),
            None => {
                let g = BankedAdjacency::create(&mgr, p.nbanks)?;
                mgr.construct::<u64>("graph", g.offset())?;
                g
            }
        };

        // --- ingest the month ---
        let metrics = Metrics::new();
        let cfg = PipelineConfig {
            workers: 2,
            batch_size: 4096,
            queue_depth: 8,
            nbanks: p.nbanks,
        };
        let rep = ingest(&mgr, &graph, b.edges.iter().copied(), &cfg, true, &metrics)?;
        // direct-mmap pays on-demand network faults *during* ingestion
        // on pages it re-reads (cold reattach): approximate with one op
        // per touched chunk of the mapping on non-first iterations.
        if mode == IoMode::DirectMmap && !first {
            let touched = (mgr.used_segment_bytes() as u64 / ps).max(1);
            ingest_sim += net.charge_io(touched / 8, 0, 1); // read-faults, some locality
        }
        let ingest_local = t0.elapsed().as_secs_f64();

        // --- flush ---
        let t1 = std::time::Instant::now();
        match mode {
            IoMode::BsMmap => {
                let st = mgr.bs_msync()?;
                // coalesced runs, parallel across files (§5.2)
                flush_sim = net.charge_io(
                    st.runs as u64,
                    st.bytes_written,
                    st.files_touched.max(1),
                );
                mgr.close()?;
            }
            IoMode::DirectMmap => {
                // kernel writeback: page-granular, low concurrency. Use
                // the page count actually dirtied this iteration — the
                // private-mode scan is the measurement instrument; the
                // charge model is what distinguishes the modes.
                let dirty = estimate_dirty_pages(&mgr)?;
                mgr.close()?;
                flush_sim = net.charge_io(dirty, dirty * ps, 2);
            }
            IoMode::StagingMmap => {
                mgr.close()?;
                // stage out: bulk copy back to the network FS
                let bytes = datastore_bytes(&dir);
                flush_sim =
                    net.charge_io(count_files(&dir), bytes, profile.concurrency);
            }
        }
        let flush_local = t1.elapsed().as_secs_f64();

        rows.push(MonthRow {
            fs: fs_name.to_string(),
            dataset: dataset.to_string(),
            mode: mode.name(),
            month: b.month,
            edges: rep.edges,
            ingest_secs: ingest_local + ingest_sim,
            flush_secs: flush_local + flush_sim,
        });
    }
    let _ = std::fs::remove_dir_all(&dir);
    Ok(rows)
}

/// One background-engine cell for the pipelined-vs-serial comparison.
/// Unlike [`run_cell`] (which reopens the store each month and models
/// the flush charge by hand), a single manager stays open across all
/// months with the simulated backend wired into its own sync path
/// ([`ManagerOptions::netfs_profile`], `sleep_scale = 1.0`) and every
/// month-boundary flush runs on the background engine — strictly serial
/// (depth 1, blocking `sync()` per month) or pipelined (depth 2,
/// `sync_async` per month + one final wait, added to the last row).
/// `flush_secs` is the stall the ingest loop observes on the persist
/// path; the simulated charge is slept inside the engine, so pipelined
/// months hide the backend write behind the next month's ingest.
pub fn run_bg_cell(
    fs_name: &str,
    dataset: &str,
    pipelined: bool,
    p: &Fig5Params,
    workdir: &Path,
) -> Result<(Vec<MonthRow>, Vec<(Op, HistogramSnapshot)>)> {
    profile_by_name_strict(fs_name)?; // fail fast, before any store exists
    let mode = if pipelined { "bg-pipelined" } else { "bg-serial" };
    let stream = match dataset {
        "wiki" => StreamConfig::wiki_like(p.months, p.first_month_edges),
        _ => StreamConfig::reddit_like(p.months, p.first_month_edges),
    };
    let batches: Vec<MonthBatch> = stream.generate();
    let dir = workdir.join(format!("fig5-{fs_name}-{dataset}-{mode}"));
    let _ = std::fs::remove_dir_all(&dir);

    let mut opts = manager_opts(p, IoMode::DirectMmap);
    opts.netfs_profile = Some(fs_name.to_string());
    opts.netfs_sleep_scale = 1.0;
    opts.sync_pipeline_depth = if pipelined { 2 } else { 1 };
    let mgr = MetallManager::create_with(&dir, opts)?;
    let graph = BankedAdjacency::create(&mgr, p.nbanks)?;
    mgr.construct::<u64>("graph", graph.offset())?;

    let mut rows = Vec::new();
    let mut last = None;
    for b in &batches {
        let t0 = std::time::Instant::now();
        let metrics = Metrics::new();
        let cfg = PipelineConfig {
            workers: 2,
            batch_size: 4096,
            queue_depth: 8,
            nbanks: p.nbanks,
        };
        let rep = ingest(&mgr, &graph, b.edges.iter().copied(), &cfg, true, &metrics)?;
        let ingest_local = t0.elapsed().as_secs_f64();

        let t1 = std::time::Instant::now();
        if pipelined {
            last = Some(mgr.sync_async()?);
        } else {
            mgr.sync()?;
        }
        rows.push(MonthRow {
            fs: fs_name.to_string(),
            dataset: dataset.to_string(),
            mode,
            month: b.month,
            edges: rep.edges,
            ingest_secs: ingest_local,
            flush_secs: t1.elapsed().as_secs_f64(),
        });
    }
    if let Some(t) = last {
        let t1 = std::time::Instant::now();
        t.wait()?;
        if let Some(r) = rows.last_mut() {
            r.flush_secs += t1.elapsed().as_secs_f64();
        }
    }
    // tail latencies of the epoch phases (and sampled alloc paths) for
    // the bench's p99/p999 rows
    let lat = mgr.latency_snapshot();
    mgr.close()?;
    let _ = std::fs::remove_dir_all(&dir);
    Ok((rows, lat))
}

/// Dirty-page estimate for the direct-mmap charge: pages written this
/// iteration ≈ segment pages touched by the month's inserts. We read the
/// kernel's per-file block deltas as a cheap proxy: count pages of the
/// mapped extent that are resident-dirty via `mincore` residency — an
/// upper bound that tracks the write working set well at these scales.
fn estimate_dirty_pages(mgr: &MetallManager) -> Result<u64> {
    let ps = page_size();
    let len = mgr.segment().mapped_len();
    if len == 0 {
        return Ok(0);
    }
    let npages = len / ps;
    let mut vec = vec![0u8; npages];
    let rc = unsafe {
        libc::mincore(
            mgr.segment().base() as *mut libc::c_void,
            len,
            vec.as_mut_ptr(),
        )
    };
    if rc != 0 {
        return Err(crate::error::Error::sys("mincore"));
    }
    Ok(vec.iter().filter(|&&b| b & 1 != 0).count() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    fn tiny() -> Fig5Params {
        Fig5Params {
            months: 3,
            first_month_edges: 2_000,
            nbanks: 32,
            chunk_size: 64 << 10,
            file_size: 1 << 20,
        }
    }

    #[test]
    fn all_modes_complete_and_accumulate() {
        let d = TempDir::new("fig5");
        for mode in IoMode::all() {
            let rows = run_cell("vast", "wiki", mode, &tiny(), d.path()).unwrap();
            assert_eq!(rows.len(), 3, "{mode:?}");
            for r in &rows {
                assert!(r.ingest_secs >= 0.0 && r.flush_secs >= 0.0);
                assert!(r.edges > 0);
            }
            // months grow
            assert!(rows[2].edges > rows[0].edges);
        }
    }

    #[test]
    fn bg_cells_complete_on_both_engine_shapes() {
        let d = TempDir::new("fig5c");
        for pipelined in [false, true] {
            let (rows, lat) = run_bg_cell("vast", "wiki", pipelined, &tiny(), d.path()).unwrap();
            assert_eq!(rows.len(), 3, "pipelined={pipelined}");
            assert!(rows.iter().all(|r| r.edges > 0 && r.flush_secs >= 0.0));
            assert!(rows[2].edges > rows[0].edges);
            // every month-boundary flush left epoch-commit samples
            let commit = lat.iter().find(|(op, _)| *op == Op::EpochCommit).unwrap();
            assert!(commit.1.count >= 3, "pipelined={pipelined}: {}", commit.1.count);
        }
    }

    #[test]
    fn unknown_backend_fails_fast_listing_profiles() {
        let d = TempDir::new("fig5d");
        let err = run_cell("gpfs", "wiki", IoMode::BsMmap, &tiny(), d.path()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("gpfs") && msg.contains("lustre"), "{msg}");
        let err = run_bg_cell("gpfs", "wiki", true, &tiny(), d.path()).unwrap_err();
        assert!(err.to_string().contains("lustre"));
    }

    #[test]
    fn expected_shape_direct_worst_on_lustre() {
        let d = TempDir::new("fig5b");
        let p = tiny();
        let total = |mode| -> f64 {
            run_cell("lustre", "wiki", mode, &p, d.path())
                .unwrap()
                .iter()
                .map(|r| r.ingest_secs + r.flush_secs)
                .sum()
        };
        let direct = total(IoMode::DirectMmap);
        let bs = total(IoMode::BsMmap);
        assert!(
            direct > bs,
            "page-granular direct-mmap must lose to bs-mmap on lustre: {direct} vs {bs}"
        );
    }
}

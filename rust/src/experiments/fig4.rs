//! Fig 4 — multi-threaded dynamic graph construction (paper §6.3).
//!
//! R-MAT SCALE `s` edges (×2, undirected) are inserted into the banked
//! adjacency list allocated by each allocator in turn. The paper's two
//! machines map to two allocator line-ups:
//! - `nvme` (Fig 4b, EPYC): metall, bip, pmemkind (default MADV_REMOVE);
//! - `optane` (Fig 4a): + pmemkind-dontneed (their fix) and ralloc.

use std::path::Path;

use crate::alloc::{ManagerOptions, MetallManager};
use crate::baselines::bip::BipAllocator;
use crate::baselines::pmemkind::{MadvMode, PmemKindAllocator};
use crate::baselines::ralloc_like::RallocLike;
use crate::baselines::BenchAllocator;
use crate::containers::BankedAdjacency;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pipeline::{ingest, PipelineConfig};
use crate::error::Result;
use crate::graph::rmat::RmatGenerator;
use crate::storage::segment::SegmentOptions;

#[derive(Clone, Debug)]
pub struct Fig4Params {
    pub scales: Vec<u32>,
    pub edge_factor: usize,
    pub threads: usize,
    pub nbanks: usize,
    pub batch: usize,
    /// "nvme" or "optane" — selects the allocator line-up.
    pub device: String,
    pub seed: u64,
    /// Segment geometry (scaled down from the paper's 256 MB files).
    pub chunk_size: usize,
    pub file_size: usize,
}

impl Default for Fig4Params {
    fn default() -> Self {
        Self {
            scales: vec![14, 16, 18],
            edge_factor: 16,
            threads: 4,
            nbanks: 1024,
            batch: 4096,
            device: "nvme".into(),
            seed: 0,
            chunk_size: 1 << 20,
            file_size: 16 << 20,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Fig4Row {
    pub allocator: &'static str,
    pub scale: u32,
    pub edges: u64,
    pub secs: f64,
    pub edges_per_sec: f64,
}

fn seg_opts(p: &Fig4Params) -> SegmentOptions {
    SegmentOptions::default().with_file_size(p.file_size).with_vm_reserve(48 << 30)
}

fn run_one<A: BenchAllocator>(
    alloc: &A,
    p: &Fig4Params,
    scale: u32,
) -> Result<Fig4Row> {
    let graph = BankedAdjacency::create(alloc, p.nbanks)?;
    let gen = RmatGenerator::graph500(scale, p.edge_factor).seed(p.seed);
    let edges = gen.generate();
    let metrics = Metrics::new();
    let cfg = PipelineConfig {
        workers: p.threads,
        batch_size: p.batch,
        queue_depth: 16,
        nbanks: p.nbanks,
    };
    let rep = ingest(alloc, &graph, edges.into_iter(), &cfg, true, &metrics)?;
    alloc.sync_all()?;
    Ok(Fig4Row {
        allocator: alloc.name(),
        scale,
        edges: rep.edges,
        secs: rep.ingest_secs,
        edges_per_sec: rep.edges_per_sec,
    })
}

/// Allocator names for a device line-up.
pub fn lineup(device: &str) -> Vec<&'static str> {
    match device {
        "optane" => vec!["metall", "bip", "pmemkind", "pmemkind-dontneed", "ralloc"],
        _ => vec!["metall", "bip", "pmemkind"],
    }
}

/// Run the full grid; calls `on_row` as rows complete (for live output).
pub fn run(
    p: &Fig4Params,
    workdir: &Path,
    mut on_row: impl FnMut(&Fig4Row),
) -> Result<Vec<Fig4Row>> {
    let mut rows = Vec::new();
    for &scale in &p.scales {
        for name in lineup(&p.device) {
            let dir = workdir.join(format!("fig4-{name}-{scale}"));
            let row = match name {
                "metall" => {
                    let opts = ManagerOptions {
                        chunk_size: p.chunk_size,
                        file_size: p.file_size,
                        vm_reserve: 48 << 30,
                        ..Default::default()
                    };
                    let m = MetallManager::create_with(&dir, opts)?;
                    let row = run_one(&m, p, scale)?;
                    m.close()?;
                    row
                }
                "bip" => {
                    let a = BipAllocator::create_with(&dir, seg_opts(p))?;
                    let row = run_one(&a, p, scale)?;
                    a.close()?;
                    row
                }
                "pmemkind" => {
                    let a = PmemKindAllocator::create_with(
                        &dir,
                        MadvMode::Remove,
                        seg_opts(p),
                        p.chunk_size,
                    )?;
                    run_one(&a, p, scale)?
                }
                "pmemkind-dontneed" => {
                    let a = PmemKindAllocator::create_with(
                        &dir,
                        MadvMode::DontNeed,
                        seg_opts(p),
                        p.chunk_size,
                    )?;
                    run_one(&a, p, scale)?
                }
                "ralloc" => {
                    let a = RallocLike::create_with(&dir, seg_opts(p), p.chunk_size)?;
                    let row = run_one(&a, p, scale)?;
                    a.close()?;
                    row
                }
                other => unreachable!("allocator {other}"),
            };
            on_row(&row);
            rows.push(row);
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    #[test]
    fn tiny_grid_produces_sane_rows() {
        let d = TempDir::new("fig4");
        let p = Fig4Params {
            scales: vec![8],
            edge_factor: 4,
            threads: 2,
            nbanks: 64,
            batch: 256,
            device: "optane".into(),
            chunk_size: 64 << 10,
            file_size: 1 << 20,
            ..Default::default()
        };
        let rows = run(&p, d.path(), |_| {}).unwrap();
        assert_eq!(rows.len(), 5);
        for r in &rows {
            assert_eq!(r.edges, 2 * 256 * 4);
            assert!(r.secs > 0.0 && r.edges_per_sec > 0.0, "{r:?}");
        }
        // all five allocators produced a row
        let names: std::collections::HashSet<_> = rows.iter().map(|r| r.allocator).collect();
        assert_eq!(names.len(), 5);
    }
}

//! §3.6 ablation — multi-file backing store: "we achieved 4.8X
//! performance improvement by dividing the original array into 512 files
//! (96 threads and PCIe NVMe SSD)" on a multithreaded out-of-core sort.
//!
//! We run the same shape: a large u64 array in a segment backed by 1 vs
//! N files, chunk-sorted by worker threads, flushed with per-file
//! parallel msync. (This box has 1 core and a page cache, so the effect
//! is smaller than the paper's 96-thread NVMe testbed — the *direction*
//! is what the ablation checks.)

use std::path::Path;
use std::time::Instant;

use crate::error::Result;
use crate::storage::segment::{SegmentOptions, SegmentStorage};
use crate::util::rng::Xoshiro256ss;

#[derive(Clone, Debug)]
pub struct OocRow {
    pub nfiles: usize,
    pub secs: f64,
}

/// Sort `total_bytes` of random u64s in a segment split into `nfiles`
/// backing files, with `threads` sorting + syncing in parallel.
pub fn run_one(workdir: &Path, total_bytes: usize, nfiles: usize, threads: usize) -> Result<OocRow> {
    let dir = workdir.join(format!("ooc-{nfiles}"));
    let _ = std::fs::remove_dir_all(&dir);
    let file_size = total_bytes / nfiles;
    let opts = SegmentOptions::default()
        .with_file_size(file_size)
        .with_vm_reserve(total_bytes * 2);
    let seg = SegmentStorage::create(&dir, opts)?;
    seg.extend_to(total_bytes)?;

    // fill with deterministic randoms
    let n = total_bytes / 8;
    {
        let data = unsafe { seg.slice_mut(0, total_bytes) };
        let words = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u64, n)
        };
        let mut rng = Xoshiro256ss::new(42);
        for w in words.iter_mut() {
            *w = rng.next_u64();
        }
    }

    let t0 = Instant::now();
    // parallel chunk sort (external-sort first pass) + parallel sync
    let per = n / threads.max(1);
    std::thread::scope(|s| {
        for t in 0..threads.max(1) {
            let seg = &seg;
            s.spawn(move || {
                let lo = t * per;
                let hi = if t == threads - 1 { n } else { lo + per };
                let words = unsafe {
                    std::slice::from_raw_parts_mut(
                        seg.base().add(lo * 8) as *mut u64,
                        hi - lo,
                    )
                };
                words.sort_unstable();
            });
        }
    });
    seg.sync(true)?;
    let secs = t0.elapsed().as_secs_f64();

    // verify sortedness per worker range (the pass's postcondition)
    {
        let words =
            unsafe { std::slice::from_raw_parts(seg.base() as *const u64, per.min(n)) };
        assert!(words.windows(2).all(|w| w[0] <= w[1]));
    }
    let _ = std::fs::remove_dir_all(&dir);
    Ok(OocRow { nfiles, secs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    #[test]
    fn runs_at_all_file_counts() {
        let d = TempDir::new("ooc");
        for nf in [1usize, 4, 16] {
            let row = run_one(d.path(), 8 << 20, nf, 2).unwrap();
            assert!(row.secs > 0.0);
        }
    }
}

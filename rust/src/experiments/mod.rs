//! Experiment drivers — one per paper table/figure (see DESIGN.md §5).
//! The `rust/benches/*` binaries and several `examples/*` are thin
//! wrappers over these so the exact same code regenerates the paper's
//! rows from both entry points.

pub mod fig4;
pub mod fig5;
pub mod fig7;
pub mod ooc;

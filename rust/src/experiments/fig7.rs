//! Fig 7 / Fig 8 — the GBTL case study (paper §7.4): graph construction
//! time with and without Metall (Fig 7), then analytics time where the
//! Metall path *reattaches* the pre-built graph instead of
//! reconstructing it (Fig 8, BFS and PageRank).

use std::path::Path;
use std::time::Instant;

use crate::alloc::{ManagerOptions, MetallManager};
use crate::error::Result;
use crate::gbtl::algorithms::{bfs_level, pagerank};
use crate::gbtl::{GrbMatrix, HeapAlloc};
use crate::graph::datasets::{self, Dataset};

#[derive(Clone, Debug)]
pub struct GbtlRow {
    pub dataset: &'static str,
    /// Fig 7: construction seconds.
    pub base_construct: f64,
    pub metall_construct: f64,
    /// Fig 8: total time to produce analytics (base = construct +
    /// analyze; metall = reattach + analyze).
    pub base_bfs_total: f64,
    pub metall_bfs_total: f64,
    pub base_pr_total: f64,
    pub metall_pr_total: f64,
}

fn mk_opts() -> ManagerOptions {
    ManagerOptions {
        chunk_size: 256 << 10,
        file_size: 4 << 20,
        vm_reserve: 8 << 30,
        ..Default::default()
    }
}

fn build_matrix<A: crate::alloc::SegmentAlloc>(a: &A, ds: &Dataset) -> Result<GrbMatrix> {
    GrbMatrix::from_edges(a, ds.n, &ds.edges)
}

/// Run the full four-dataset study.
pub fn run(workdir: &Path, mut on_row: impl FnMut(&GbtlRow)) -> Result<Vec<GbtlRow>> {
    let mut rows = Vec::new();
    for ds in datasets::all() {
        // ---------- Fig 7: construction ----------
        // Base GBTL: DRAM (HeapAlloc)
        let t = Instant::now();
        let heap = HeapAlloc::new()?;
        let base_m = build_matrix(&heap, &ds)?;
        let base_construct = t.elapsed().as_secs_f64();

        // GBTL + Metall: persistent store on "SSD" (local disk)
        let dir = workdir.join(format!("gbtl-{}", ds.name));
        let _ = std::fs::remove_dir_all(&dir);
        let t = Instant::now();
        let mgr = MetallManager::create_with(&dir, mk_opts())?;
        let pm = build_matrix(&mgr, &ds)?;
        mgr.construct::<GrbMatrix>("matrix", pm)?;
        mgr.close()?; // construction cost includes the flush to storage
        let metall_construct = t.elapsed().as_secs_f64();

        // ---------- Fig 8: analytics ----------
        // Base: must reconstruct then analyze (no persistence).
        let t = Instant::now();
        let heap2 = HeapAlloc::new()?;
        let m2 = build_matrix(&heap2, &ds)?;
        let _levels = bfs_level(&heap2, &m2, 0);
        let base_bfs_total = t.elapsed().as_secs_f64();

        let t = Instant::now();
        let heap3 = HeapAlloc::new()?;
        let m3 = build_matrix(&heap3, &ds)?;
        let (_r, _) = pagerank(&heap3, &m3, 0.85, 50, 1e-9);
        let base_pr_total = t.elapsed().as_secs_f64();

        // Metall: reattach the pre-built matrix, then analyze.
        let t = Instant::now();
        let mgr = MetallManager::open_read_only(&dir)?;
        let pm: GrbMatrix = mgr.read(mgr.find::<GrbMatrix>("matrix")?.unwrap());
        let levels_m = bfs_level(&mgr, &pm, 0);
        let metall_bfs_total = t.elapsed().as_secs_f64();

        let t = Instant::now();
        let mgr2 = MetallManager::open_read_only(&dir)?;
        let pm2: GrbMatrix = mgr2.read(mgr2.find::<GrbMatrix>("matrix")?.unwrap());
        let (ranks_m, _) = pagerank(&mgr2, &pm2, 0.85, 50, 1e-9);
        let metall_pr_total = t.elapsed().as_secs_f64();

        // correctness cross-check: persistent path == DRAM path
        let levels_b = bfs_level(&heap, &base_m, 0);
        assert_eq!(levels_b, levels_m, "{}: BFS mismatch", ds.name);
        let (ranks_b, _) = pagerank(&heap, &base_m, 0.85, 50, 1e-9);
        for (a, b) in ranks_b.iter().zip(&ranks_m) {
            assert!((a - b).abs() < 1e-10, "{}: PR mismatch", ds.name);
        }

        let row = GbtlRow {
            dataset: ds.name,
            base_construct,
            metall_construct,
            base_bfs_total,
            metall_bfs_total,
            base_pr_total,
            metall_pr_total,
        };
        on_row(&row);
        rows.push(row);
        let _ = std::fs::remove_dir_all(&dir);
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    #[test]
    fn study_runs_and_reattach_wins() {
        let d = TempDir::new("fig7");
        let rows = run(d.path(), |_| {}).unwrap();
        assert_eq!(rows.len(), 4);
        for r in &rows {
            // Fig 8's claim: reattach+analyze beats construct+analyze
            assert!(
                r.metall_bfs_total < r.base_bfs_total,
                "{}: {} vs {}",
                r.dataset,
                r.metall_bfs_total,
                r.base_bfs_total
            );
        }
    }
}

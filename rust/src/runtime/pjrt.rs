//! Thin wrapper over the `xla` crate's PJRT client.
//!
//! Interchange format is HLO **text** (not serialized `HloModuleProto`):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see aot.py and
//! /opt/xla-example/README.md).

use std::path::Path;

use crate::error::{Error, Result};

fn rt<E: std::fmt::Display>(ctx: &str) -> impl FnOnce(E) -> Error + '_ {
    move |e| Error::Runtime(format!("{ctx}: {e}"))
}

/// A PJRT CPU client. One per process is plenty; cheap to share.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(rt("PjRtClient::cpu"))?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it to an executable.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact(format!("non-utf8 path {path:?}")))?,
        )
        .map_err(rt(&format!("parse HLO text {path:?}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(rt(&format!("compile {path:?}")))?;
        Ok(Executable { exe })
    }
}

/// A compiled executable. The lowered jax functions return a tuple, so
/// `run` always decomposes the single tuple output.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literal inputs; returns the decomposed output tuple.
    /// Accepts owned literals or references (no copies for loop-invariant
    /// operands).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let bufs = self.exe.execute::<L>(inputs).map_err(rt("execute"))?;
        let out = bufs[0][0].to_literal_sync().map_err(rt("to_literal_sync"))?;
        out.to_tuple().map_err(rt("decompose output tuple"))
    }
}

/// Literal construction helpers.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(rt("reshape f32 literal"))
}

pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(rt("reshape i32 literal"))
}

pub fn lit_u32(data: &[u32], dims: &[i64]) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(rt("reshape u32 literal"))
}

pub fn to_vec_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(rt("literal to_vec f32"))
}

pub fn to_vec_u32(l: &xla::Literal) -> Result<Vec<u32>> {
    l.to_vec::<u32>().map_err(rt("literal to_vec u32"))
}

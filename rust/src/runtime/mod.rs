//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//! Python is **never** on this path — the artifacts are plain files.

pub mod pjrt;
pub mod manifest;
pub mod engine;

//! The analytics engine: picks a compiled shape variant for a graph,
//! pads the inputs, and drives iterative algorithms (PageRank power
//! iteration, BFS level sweeps) through the PJRT executables.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::graph::ell::EllGraph;
use crate::runtime::manifest::{Kind, Manifest, Variant};
use crate::runtime::pjrt::{lit_f32, lit_i32, lit_u32, to_vec_f32, to_vec_u32, Executable, PjrtRuntime};

/// Outcome of an engine-run analytic, with timing split out so the GBTL
/// demonstration (Fig 8) can report reattach-vs-analyze phases.
#[derive(Clone, Debug)]
pub struct AnalyticsRun {
    pub iterations: usize,
    pub compile_secs: f64,
    pub exec_secs: f64,
    pub values: Vec<f32>,
}

/// PJRT-backed analytics engine with an executable cache.
pub struct AnalyticsEngine {
    rt: PjrtRuntime,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl AnalyticsEngine {
    pub fn new(artifacts_dir: impl Into<std::path::PathBuf>) -> Result<Self> {
        Ok(Self {
            rt: PjrtRuntime::cpu()?,
            manifest: Manifest::load(artifacts_dir)?,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executable(&self, v: &Variant) -> Result<std::sync::Arc<Executable>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(e) = cache.get(&v.file) {
            return Ok(e.clone());
        }
        let exe = std::sync::Arc::new(self.rt.load_hlo_text(&self.manifest.path_of(v))?);
        cache.insert(v.file.clone(), exe.clone());
        Ok(exe)
    }

    fn pick(&self, kind: Kind, g: &EllGraph) -> Result<&Variant> {
        self.manifest.pick(kind, g.n, g.f).ok_or_else(|| {
            Error::Artifact(format!(
                "no {kind:?} variant fits n={} f={} — extend the AOT ladder",
                g.n, g.f
            ))
        })
    }

    /// PageRank power iteration. Stops at `max_iters` or when the L1 rank
    /// delta falls below `tol` (checked host-side between executions).
    pub fn pagerank(&self, g: &EllGraph, max_iters: usize, tol: f32) -> Result<AnalyticsRun> {
        let v = self.pick(Kind::Pagerank, g)?;
        let alpha = v.alpha.unwrap_or(0.85) as f32;
        let t0 = Instant::now();
        let exe = self.executable(v)?;
        let compile_secs = t0.elapsed().as_secs_f64();

        let gp = g.padded(v.n, v.f);
        let n_pad = v.n as i64;
        let f_pad = v.f as i64;
        let w = v.w as i64;

        // base/dweight vectors: real vertices only (exact padding).
        let n_true = g.n as f32;
        let mut base = vec![0f32; v.n];
        let mut dweight = vec![0f32; v.n];
        for i in 0..g.n {
            base[i] = (1.0 - alpha) / n_true;
            dweight[i] = alpha / n_true;
        }
        let mut ranks = vec![0f32; v.n];
        for r in ranks.iter_mut().take(g.n) {
            *r = 1.0 / n_true;
        }

        let l_idx = lit_i32(&gp.idx, &[f_pad, w])?;
        let l_val = lit_f32(&gp.val, &[f_pad, w])?;
        let l_owner = lit_i32(&gp.owner, &[f_pad])?;
        let l_inv = lit_f32(&gp.inv_outdeg, &[n_pad])?;
        let l_dang = lit_f32(&gp.dangling, &[n_pad])?;
        let l_base = lit_f32(&base, &[n_pad])?;
        let l_dw = lit_f32(&dweight, &[n_pad])?;

        let t1 = Instant::now();
        let mut iters = 0;
        for _ in 0..max_iters {
            let l_ranks = lit_f32(&ranks, &[n_pad])?;
            let out = exe.run(&[
                &l_ranks, &l_idx, &l_val, &l_owner, &l_inv, &l_dang, &l_base, &l_dw,
            ])?;
            let new_ranks = to_vec_f32(&out[0])?;
            iters += 1;
            let delta: f32 =
                new_ranks.iter().zip(&ranks).map(|(a, b)| (a - b).abs()).sum();
            ranks = new_ranks;
            if delta < tol {
                break;
            }
        }
        let exec_secs = t1.elapsed().as_secs_f64();
        ranks.truncate(g.n);
        Ok(AnalyticsRun { iterations: iters, compile_secs, exec_secs, values: ranks })
    }

    /// BFS from `source`; returns levels (-1 unreachable) as f32-encoded
    /// in `values` (cast to i64 by callers as needed).
    pub fn bfs(&self, g: &EllGraph, source: usize) -> Result<AnalyticsRun> {
        let v = self.pick(Kind::Bfs, g)?;
        let t0 = Instant::now();
        let exe = self.executable(v)?;
        let compile_secs = t0.elapsed().as_secs_f64();

        let gp = g.padded(v.n, v.f);
        let n_pad = v.n as i64;
        let f_pad = v.f as i64;
        let w = v.w as i64;

        let l_idx = lit_i32(&gp.idx, &[f_pad, w])?;
        let l_val = lit_f32(&gp.val, &[f_pad, w])?;
        let l_owner = lit_i32(&gp.owner, &[f_pad])?;

        let mut frontier = vec![0f32; v.n];
        frontier[source] = 1.0;
        let mut visited = frontier.clone();
        let mut levels = vec![-1f32; v.n];
        levels[source] = 0.0;

        let t1 = Instant::now();
        let mut lvl = 0f32;
        let mut iters = 0;
        loop {
            let nf: f32 = frontier.iter().sum();
            if nf == 0.0 || iters >= g.n {
                break;
            }
            lvl += 1.0;
            let l_front = lit_f32(&frontier, &[n_pad])?;
            let l_vis = lit_f32(&visited, &[n_pad])?;
            let out = exe.run(&[&l_front, &l_vis, &l_idx, &l_val, &l_owner])?;
            frontier = to_vec_f32(&out[0])?;
            visited = to_vec_f32(&out[1])?;
            for i in 0..v.n {
                if frontier[i] > 0.0 && levels[i] < 0.0 {
                    levels[i] = lvl;
                }
            }
            iters += 1;
        }
        let exec_secs = t1.elapsed().as_secs_f64();
        levels.truncate(g.n);
        Ok(AnalyticsRun { iterations: iters, compile_secs, exec_secs, values: levels })
    }

    /// Edge→bank bucketing through the AOT kernel. Falls back to exact
    /// native hashing for the tail that does not fill a compiled batch.
    pub fn bucket(&self, src: &[u32], nbanks: u32) -> Result<Vec<u32>> {
        let mut out = Vec::with_capacity(src.len());
        let mut rest = src;
        while !rest.is_empty() {
            let v = self
                .manifest
                .variants
                .iter()
                .filter(|v| v.kind == Kind::Bucket && v.f == nbanks as usize && v.n <= rest.len())
                .max_by_key(|v| v.n);
            match v {
                Some(v) => {
                    let exe = self.executable(v)?;
                    let batch = &rest[..v.n];
                    let res = exe.run(&[lit_u32(batch, &[v.n as i64])?])?;
                    out.extend(to_vec_u32(&res[0])?);
                    rest = &rest[v.n..];
                }
                None => {
                    // native tail
                    out.extend(rest.iter().map(|&s| crate::graph::bucket_hash32(s, nbanks)));
                    rest = &[];
                }
            }
        }
        Ok(out)
    }
}

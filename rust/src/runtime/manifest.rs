//! Parser for `artifacts/manifest.txt` — the shape-variant ladder emitted
//! by `python/compile/aot.py`:
//!
//! ```text
//! pagerank <n> <f> <w> <alpha> <file>
//! bfs      <n> <f> <w> -       <file>
//! bucket   <batch> <nbanks> -  -     <file>
//! ```

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Kind {
    Pagerank,
    Bfs,
    Bucket,
}

impl Kind {
    fn parse(s: &str) -> Option<Kind> {
        match s {
            "pagerank" => Some(Kind::Pagerank),
            "bfs" => Some(Kind::Bfs),
            "bucket" => Some(Kind::Bucket),
            _ => None,
        }
    }
}

/// One compiled shape variant.
#[derive(Clone, Debug)]
pub struct Variant {
    pub kind: Kind,
    /// Vertex count (pagerank/bfs) or batch size (bucket).
    pub n: usize,
    /// Fragment count (pagerank/bfs) or bank count (bucket).
    pub f: usize,
    /// ELL width (pagerank/bfs only).
    pub w: usize,
    /// Damping factor compiled into pagerank variants.
    pub alpha: Option<f64>,
    pub file: String,
}

/// The parsed artifact ladder.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: Vec<Variant>,
}

impl Manifest {
    pub fn load(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {path:?} — run `make artifacts` first ({e})"
            ))
        })?;
        Self::parse(&dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let mut variants = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            if toks.len() != 6 {
                return Err(Error::Artifact(format!(
                    "manifest line {}: expected 6 fields, got {}",
                    lineno + 1,
                    toks.len()
                )));
            }
            let kind = Kind::parse(toks[0]).ok_or_else(|| {
                Error::Artifact(format!("manifest line {}: unknown kind {}", lineno + 1, toks[0]))
            })?;
            let num = |s: &str, what: &str| -> Result<usize> {
                s.parse().map_err(|_| {
                    Error::Artifact(format!(
                        "manifest line {}: bad {what} `{s}`",
                        lineno + 1
                    ))
                })
            };
            let n = num(toks[1], "n")?;
            let f = num(toks[2], "f")?;
            let w = if toks[3] == "-" { 0 } else { num(toks[3], "w")? };
            let alpha = if toks[4] == "-" { None } else { toks[4].parse().ok() };
            let file = toks[5].to_string();
            if !dir.join(&file).exists() {
                return Err(Error::Artifact(format!(
                    "manifest references missing artifact {file}"
                )));
            }
            variants.push(Variant { kind, n, f, w, alpha, file });
        }
        if variants.is_empty() {
            return Err(Error::Artifact("manifest has no variants".into()));
        }
        Ok(Self { dir: dir.to_path_buf(), variants })
    }

    /// Smallest variant of `kind` with `n >= need_n && f >= need_f`.
    pub fn pick(&self, kind: Kind, need_n: usize, need_f: usize) -> Option<&Variant> {
        self.variants
            .iter()
            .filter(|v| v.kind == kind && v.n >= need_n && v.f >= need_f)
            .min_by_key(|v| (v.n, v.f))
    }

    pub fn path_of(&self, v: &Variant) -> PathBuf {
        self.dir.join(&v.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    fn setup(lines: &str, files: &[&str]) -> (TempDir, Result<Manifest>) {
        let d = TempDir::new("manifest");
        for f in files {
            std::fs::write(d.join(f), "dummy").unwrap();
        }
        let m = Manifest::parse(d.path(), lines);
        (d, m)
    }

    #[test]
    fn parse_and_pick() {
        let (_d, m) = setup(
            "pagerank 256 256 32 0.85 a.hlo\npagerank 1024 4096 32 0.85 b.hlo\nbfs 256 256 32 - c.hlo\nbucket 4096 1024 - - d.hlo\n",
            &["a.hlo", "b.hlo", "c.hlo", "d.hlo"],
        );
        let m = m.unwrap();
        assert_eq!(m.variants.len(), 4);
        let v = m.pick(Kind::Pagerank, 100, 100).unwrap();
        assert_eq!(v.file, "a.hlo");
        let v = m.pick(Kind::Pagerank, 100, 1000).unwrap();
        assert_eq!(v.file, "b.hlo");
        assert!(m.pick(Kind::Pagerank, 10_000, 1).is_none());
        assert_eq!(m.pick(Kind::Bucket, 4096, 0).unwrap().file, "d.hlo");
        assert_eq!(m.pick(Kind::Pagerank, 256, 256).unwrap().alpha, Some(0.85));
    }

    #[test]
    fn rejects_missing_file() {
        let (_d, m) = setup("pagerank 256 256 32 0.85 missing.hlo\n", &[]);
        assert!(m.is_err());
    }

    #[test]
    fn rejects_malformed() {
        let (_d, m) = setup("pagerank 256 256\n", &[]);
        assert!(m.is_err());
        let (_d2, m2) = setup("warp 1 2 3 4 x.hlo\n", &["x.hlo"]);
        assert!(m2.is_err());
        let (_d3, m3) = setup("", &[]);
        assert!(m3.is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let (_d, m) = setup("# comment\n\nbfs 256 256 32 - c.hlo\n", &["c.hlo"]);
        assert_eq!(m.unwrap().variants.len(), 1);
    }
}

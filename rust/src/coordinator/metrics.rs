//! Lightweight metrics: counters and phase timers for the pipeline and
//! the experiment harness.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// A named set of monotonically increasing counters plus accumulated
/// phase durations. Cheap to share behind an `Arc`.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, AtomicU64>>,
    timers_ns: Mutex<BTreeMap<String, AtomicU64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, name: &str, v: u64) {
        let mut m = self.counters.lock().unwrap();
        m.entry(name.to_string()).or_default().fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Time a closure, accumulating into phase `name`.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let r = f();
        self.add_time(name, t0.elapsed().as_nanos() as u64);
        r
    }

    pub fn add_time(&self, name: &str, ns: u64) {
        let mut m = self.timers_ns.lock().unwrap();
        m.entry(name.to_string()).or_default().fetch_add(ns, Ordering::Relaxed);
    }

    pub fn seconds(&self, name: &str) -> f64 {
        self.timers_ns
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed) as f64 / 1e9)
            .unwrap_or(0.0)
    }

    /// Snapshot all values for reporting.
    pub fn snapshot(&self) -> (BTreeMap<String, u64>, BTreeMap<String, f64>) {
        let c = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let t = self
            .timers_ns
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed) as f64 / 1e9))
            .collect();
        (c, t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_timers() {
        let m = Metrics::new();
        m.add("edges", 5);
        m.add("edges", 7);
        assert_eq!(m.get("edges"), 12);
        assert_eq!(m.get("missing"), 0);
        let v = m.time("phase", || 21 * 2);
        assert_eq!(v, 42);
        assert!(m.seconds("phase") >= 0.0);
        let (c, t) = m.snapshot();
        assert_eq!(c["edges"], 12);
        assert!(t.contains_key("phase"));
    }

    #[test]
    fn concurrent_adds() {
        let m = Metrics::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        m.add("n", 1);
                    }
                });
            }
        });
        assert_eq!(m.get("n"), 4000);
    }
}

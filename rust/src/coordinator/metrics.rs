//! Lightweight metrics: counters and phase timers for the pipeline and
//! the experiment harness, plus the allocator-counter bridge
//! ([`record_alloc_stats`]) that folds the manager's aggregate totals and
//! per-shard contention counters into a metrics set.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;
use std::time::Instant;

use crate::alloc::bg_sync::BgSyncStats;
use crate::alloc::bin_dir::ShardStatsSnapshot;
use crate::alloc::manager::{AttachStats, HealthStats, PlacementReport, StatsSnapshot, SyncStats};
use crate::containers::oplog::OpLogStats;
use crate::telemetry::export::OpLatency;
use crate::telemetry::histogram::HistogramSnapshot;
use crate::telemetry::Op;

/// A named set of monotonically increasing counters plus accumulated
/// phase durations. Cheap to share behind an `Arc`.
///
/// The maps are guarded by `RwLock`, not `Mutex`: once a key exists
/// (steady state — key sets stabilize after the first report), updates
/// take the *shared* lock and `fetch_add`/`store` on the existing
/// atomic, so concurrent recorders from many threads never serialize on
/// each other. The exclusive lock is only taken the first time a key is
/// seen.
#[derive(Default)]
pub struct Metrics {
    counters: RwLock<BTreeMap<String, AtomicU64>>,
    timers_ns: RwLock<BTreeMap<String, AtomicU64>>,
}

/// Shared-lock fast path: update `name` in place if present, else take
/// the write lock and insert. `store` overwrites (gauge semantics);
/// otherwise the value is added (counter semantics).
fn upsert(map: &RwLock<BTreeMap<String, AtomicU64>>, name: &str, v: u64, store: bool) {
    {
        let m = map.read().unwrap();
        if let Some(c) = m.get(name) {
            if store {
                c.store(v, Ordering::Relaxed);
            } else {
                c.fetch_add(v, Ordering::Relaxed);
            }
            return;
        }
    }
    let mut m = map.write().unwrap();
    // re-check: another thread may have inserted between the locks
    let c = m.entry(name.to_string()).or_default();
    if store {
        c.store(v, Ordering::Relaxed);
    } else {
        c.fetch_add(v, Ordering::Relaxed);
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, name: &str, v: u64) {
        upsert(&self.counters, name, v, false);
    }

    /// Overwrite `name` with `v` (gauge semantics — used by the latency
    /// bridge, whose quantiles are not monotonic).
    pub fn set(&self, name: &str, v: u64) {
        upsert(&self.counters, name, v, true);
    }

    pub fn get(&self, name: &str) -> u64 {
        self.counters
            .read()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Time a closure, accumulating into phase `name`.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let r = f();
        self.add_time(name, t0.elapsed().as_nanos() as u64);
        r
    }

    pub fn add_time(&self, name: &str, ns: u64) {
        upsert(&self.timers_ns, name, ns, false);
    }

    pub fn seconds(&self, name: &str) -> f64 {
        self.timers_ns
            .read()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed) as f64 / 1e9)
            .unwrap_or(0.0)
    }

    /// Snapshot all values for reporting.
    pub fn snapshot(&self) -> (BTreeMap<String, u64>, BTreeMap<String, f64>) {
        let c = self
            .counters
            .read()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let t = self
            .timers_ns
            .read()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed) as f64 / 1e9))
            .collect();
        (c, t)
    }
}

/// Fold an allocator snapshot into `m`: manager-wide totals under the
/// pre-sharding `alloc.*` names (backward compatible — the shard count
/// never changes these keys or their meaning) and per-shard contention
/// counters under `alloc.shard<N>.*`. Counters are monotonic adds: call
/// once per snapshot, or feed deltas.
pub fn record_alloc_stats(m: &Metrics, totals: &StatsSnapshot, shards: &[ShardStatsSnapshot]) {
    m.add("alloc.allocs", totals.allocs);
    m.add("alloc.deallocs", totals.deallocs);
    m.add("alloc.cache_hits", totals.cache_hits);
    m.add("alloc.fast_claims", totals.fast_claims);
    m.add("alloc.fresh_chunks", totals.fresh_chunks);
    m.add("alloc.freed_chunks", totals.freed_chunks);
    m.add("alloc.large_allocs", totals.large_allocs);
    for s in shards {
        let k = |name: &str| format!("alloc.shard{}.{name}", s.shard);
        m.add(&k("fast_claims"), s.fast_claims);
        m.add(&k("fresh_chunks"), s.fresh_chunks);
        m.add(&k("freed_chunks"), s.freed_chunks);
        m.add(&k("remote_frees"), s.remote_frees);
        m.add(&k("remote_drained"), s.remote_drained);
        m.add(&k("exclusive_acquires"), s.exclusive_acquires);
        m.add(&k("first_touch_chunks"), s.first_touch_chunks);
        m.add(&k("bound_chunks"), s.bound_chunks);
    }
}

/// Fold a NUMA placement report into `m`: per-shard node-locality
/// counters under `alloc.shard<N>.node_local_pages` (plus
/// remote/unknown/total) and the segment-wide buckets under
/// `alloc.placement.*`. Counters are monotonic adds: call once per
/// report, or feed deltas.
pub fn record_placement(m: &Metrics, r: &PlacementReport) {
    m.add("alloc.placement.total_pages", r.total_pages);
    m.add("alloc.placement.free_pages", r.free_pages);
    m.add("alloc.placement.large_pages", r.large_pages);
    for s in &r.per_shard {
        let k = |name: &str| format!("alloc.shard{}.{name}", s.shard);
        m.add(&k("node_local_pages"), s.node_local_pages);
        m.add(&k("remote_pages"), s.remote_pages);
        m.add(&k("unknown_pages"), s.unknown_pages);
        m.add(&k("placement_pages"), s.pages);
    }
}

/// Fold one sync's [`SyncStats`] into `m` under `alloc.sync.*`: per-sync
/// gauges are added as deltas (the struct's last-sync fields describe
/// exactly one sync), so calling once after every `sync()` accumulates
/// totals. `alloc.sync.count` / `alloc.sync.manifest_commits` count
/// invocations and real commits (a no-op sync adds zero everywhere
/// else).
pub fn record_sync_stats(m: &Metrics, s: &SyncStats) {
    m.add("alloc.sync.count", 1);
    // the last sync committed a manifest iff it had dirty sections
    m.add("alloc.sync.manifest_commits", u64::from(s.dirty_sections > 0));
    m.add("alloc.sync.dirty_sections", s.dirty_sections);
    m.add("alloc.sync.section_bytes", s.section_bytes_written);
    m.add("alloc.sync.data_chunks", s.data_chunks_flushed);
    m.add("alloc.sync.data_bytes", s.data_bytes_flushed);
    m.add("alloc.sync.flush_micros", s.flush_micros);
    m.add("alloc.sync.sim_flush_micros", s.sim_flush_micros);
    m.add("alloc.sync.cache_slots_preserved", s.cache_slots_preserved);
}

/// Fold a background-engine snapshot into `m` under `alloc.bgsync.*`.
/// [`BgSyncStats`] counters are cumulative over the engine's lifetime
/// (unlike the per-sync [`SyncStats`] gauges), so call this once per
/// manager at report time — or feed deltas when sampling repeatedly.
pub fn record_bg_sync_stats(m: &Metrics, s: &BgSyncStats) {
    m.add("alloc.bgsync.flushes", s.flushes);
    m.add("alloc.bgsync.flush_failures", s.flush_failures);
    m.add("alloc.bgsync.watermark_hits", s.watermark_triggers);
    m.add("alloc.bgsync.ceiling_hits", s.ceiling_triggers);
    m.add("alloc.bgsync.interval_fires", s.interval_triggers);
    m.add("alloc.bgsync.explicit_requests", s.explicit_requests);
    m.add("alloc.bgsync.section_bytes", s.section_bytes_flushed);
    m.add("alloc.bgsync.data_bytes", s.data_bytes_flushed);
    m.add("alloc.bgsync.writer_stalls", s.writer_stalls);
    m.add("alloc.bgsync.writer_stall_micros", s.writer_stall_micros);
    m.add("alloc.bgsync.watermark_bytes", s.watermark_bytes);
    m.add("alloc.bgsync.ceiling_bytes", s.ceiling_bytes);
    m.add("alloc.bgsync.pipeline_depth", s.pipeline_depth);
    m.add("alloc.bgsync.pipeline_peak_in_flight", s.pipeline_peak_in_flight);
    m.add("alloc.bgsync.adaptive_watermark_bytes", s.adaptive_watermark_bytes);
    m.add("alloc.bgsync.measured_bandwidth_bps", s.measured_bandwidth_bps);
    m.add("alloc.bgsync.epochs_committed", s.epochs_committed);
}

/// Fold a manager's container op-log counters into `m` under
/// `alloc.oplog.*`. [`OpLogStats`] counters are cumulative over the
/// manager's lifetime (recovery counters are set once at open), so call
/// this once per manager at report time — or feed deltas when sampling
/// repeatedly.
pub fn record_oplog_stats(m: &Metrics, s: &OpLogStats) {
    m.add("alloc.oplog.appended", s.appended);
    m.add("alloc.oplog.committed", s.committed);
    m.add("alloc.oplog.forced_syncs", s.forced_syncs);
    m.add("alloc.oplog.forced_sync_errors", s.forced_sync_errors);
    m.add("alloc.oplog.recovered_forward", s.recovered_forward);
    m.add("alloc.oplog.recovered_rollback", s.recovered_rollback);
    m.add("alloc.oplog.recovered_adopted", s.recovered_adopted);
    m.add("alloc.oplog.recovered_released", s.recovered_released);
    m.add("alloc.oplog.recovery_anomalies", s.recovery_anomalies);
    m.add("alloc.oplog.validate_records", s.validate_records);
}

/// Fold a manager's failure-health snapshot into `m`: classified flush
/// failures and allocation-path rollbacks under `alloc.faults.*`, and
/// the degraded flag as the 0/1 gauge `alloc.health.degraded`.
/// [`HealthStats`] counters are cumulative over the manager's lifetime,
/// so call this once per manager at report time — or feed deltas when
/// sampling repeatedly.
pub fn record_health_stats(m: &Metrics, s: &HealthStats) {
    m.add("alloc.faults.transient_failures", s.transient_failures);
    m.add("alloc.faults.permanent_failures", s.permanent_failures);
    m.add("alloc.faults.extend_rollbacks", s.extend_rollbacks);
    m.add("alloc.health.degraded", u64::from(s.degraded));
}

/// Fold one reader's [`AttachStats`] into `m` under `alloc.attach.*`.
/// The struct is cumulative over one attach's lifetime (created /
/// reused / refreshes grow monotonically; `staleness_epochs` is the
/// value at the last attach/refresh decision), so call this once per
/// reader at report time — or feed deltas when sampling repeatedly.
pub fn record_attach_stats(m: &Metrics, s: &AttachStats) {
    m.add("alloc.attach.count", 1);
    m.add("alloc.attach.micros", s.attach_micros);
    m.add("alloc.attach.refreshes", s.refreshes);
    m.add("alloc.attach.chunks_overlaid", s.chunks_overlaid);
    m.add("alloc.attach.side_copies_created", s.side_copies_created);
    m.add("alloc.attach.side_copies_reused", s.side_copies_reused);
    m.add("alloc.attach.staleness_epochs", s.staleness_epochs);
}

/// Fold per-op latency quantiles from
/// [`crate::telemetry::Telemetry::snapshot`] into `m` as
/// `alloc.lat.<op>.{p50,p90,p99,p999,count}` gauges (nanoseconds except
/// `count`). Quantiles are *set*, not added — they describe the
/// histogram's current state, so re-recording refreshes them in place.
/// Ops with no samples are skipped (keys never exist with bogus zeros).
pub fn record_latency_stats(m: &Metrics, snaps: &[(Op, HistogramSnapshot)]) {
    for (op, snap) in snaps {
        if snap.count == 0 {
            continue;
        }
        let l = OpLatency::from_snapshot(*op, snap);
        let k = |q: &str| format!("alloc.lat.{}.{q}", l.op);
        m.set(&k("p50"), l.p50);
        m.set(&k("p90"), l.p90);
        m.set(&k("p99"), l.p99);
        m.set(&k("p999"), l.p999);
        m.set(&k("count"), l.count);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_timers() {
        let m = Metrics::new();
        m.add("edges", 5);
        m.add("edges", 7);
        assert_eq!(m.get("edges"), 12);
        assert_eq!(m.get("missing"), 0);
        let v = m.time("phase", || 21 * 2);
        assert_eq!(v, 42);
        assert!(m.seconds("phase") >= 0.0);
        let (c, t) = m.snapshot();
        assert_eq!(c["edges"], 12);
        assert!(t.contains_key("phase"));
    }

    #[test]
    fn alloc_stats_bridge_keeps_totals_backward_compatible() {
        let m = Metrics::new();
        let totals = StatsSnapshot {
            allocs: 10,
            deallocs: 4,
            cache_hits: 3,
            fast_claims: 7,
            fresh_chunks: 2,
            freed_chunks: 1,
            large_allocs: 0,
        };
        let shards = vec![
            ShardStatsSnapshot { shard: 0, fast_claims: 5, fresh_chunks: 1, ..Default::default() },
            ShardStatsSnapshot {
                shard: 1,
                fast_claims: 2,
                fresh_chunks: 1,
                freed_chunks: 1,
                remote_frees: 6,
                remote_drained: 6,
                exclusive_acquires: 3,
                ..Default::default()
            },
        ];
        record_alloc_stats(&m, &totals, &shards);
        // pre-sharding keys carry the aggregates
        assert_eq!(m.get("alloc.allocs"), 10);
        assert_eq!(m.get("alloc.fast_claims"), 7);
        // per-shard contention counters sum to the totals
        assert_eq!(
            m.get("alloc.shard0.fast_claims") + m.get("alloc.shard1.fast_claims"),
            m.get("alloc.fast_claims")
        );
        assert_eq!(m.get("alloc.shard1.remote_frees"), 6);
        assert_eq!(m.get("alloc.shard1.exclusive_acquires"), 3);
    }

    #[test]
    fn placement_bridge_exports_node_locality() {
        use crate::alloc::manager::{PlacementSource, ShardPlacement};
        let m = Metrics::new();
        let report = PlacementReport {
            per_shard: vec![
                ShardPlacement {
                    shard: 0,
                    node: 0,
                    pages: 128,
                    node_local_pages: 126,
                    remote_pages: 2,
                    unknown_pages: 0,
                },
                ShardPlacement {
                    shard: 1,
                    node: 1,
                    pages: 64,
                    node_local_pages: 64,
                    ..Default::default()
                },
            ],
            large_pages: 32,
            free_pages: 16,
            total_pages: 240,
            source: PlacementSource::Recorded,
        };
        assert_eq!(report.accounted_pages(), 240);
        record_placement(&m, &report);
        assert_eq!(m.get("alloc.shard0.node_local_pages"), 126);
        assert_eq!(m.get("alloc.shard0.remote_pages"), 2);
        assert_eq!(m.get("alloc.shard1.node_local_pages"), 64);
        assert_eq!(m.get("alloc.shard1.placement_pages"), 64);
        assert_eq!(m.get("alloc.placement.total_pages"), 240);
        assert_eq!(m.get("alloc.placement.large_pages"), 32);
    }

    #[test]
    fn sync_bridge_accumulates_per_sync_deltas() {
        let m = Metrics::new();
        // a full first sync…
        record_sync_stats(
            &m,
            &SyncStats {
                syncs: 1,
                manifest_commits: 1,
                dirty_sections: 9,
                total_sections: 9,
                section_bytes_written: 4096,
                data_chunks_flushed: 32,
                data_bytes_flushed: 32 << 16,
                flush_micros: 1500,
                sim_flush_micros: 900,
                cache_slots_preserved: 12,
            },
        );
        // …then a no-op sync adds only the invocation count
        record_sync_stats(
            &m,
            &SyncStats { syncs: 2, manifest_commits: 1, total_sections: 9, ..Default::default() },
        );
        assert_eq!(m.get("alloc.sync.count"), 2);
        assert_eq!(m.get("alloc.sync.manifest_commits"), 1);
        assert_eq!(m.get("alloc.sync.dirty_sections"), 9);
        assert_eq!(m.get("alloc.sync.section_bytes"), 4096);
        assert_eq!(m.get("alloc.sync.data_chunks"), 32);
        assert_eq!(m.get("alloc.sync.flush_micros"), 1500);
        assert_eq!(m.get("alloc.sync.sim_flush_micros"), 900);
        assert_eq!(m.get("alloc.sync.cache_slots_preserved"), 12);
    }

    #[test]
    fn bg_sync_bridge_exports_engine_counters() {
        let m = Metrics::new();
        let s = BgSyncStats {
            flushes: 5,
            flush_failures: 1,
            watermark_triggers: 3,
            ceiling_triggers: 0,
            interval_triggers: 1,
            explicit_requests: 1,
            section_bytes_flushed: 2048,
            data_bytes_flushed: 1 << 20,
            writer_stalls: 2,
            writer_stall_micros: 750,
            watermark_bytes: 4 << 20,
            ceiling_bytes: 16 << 20,
            pipeline_depth: 2,
            pipeline_peak_in_flight: 2,
            adaptive_watermark_bytes: 9 << 20,
            measured_bandwidth_bps: 3_000_000_000,
            epochs_committed: 4,
            engine_running: true,
            engine_dead: false,
        };
        record_bg_sync_stats(&m, &s);
        assert_eq!(m.get("alloc.bgsync.flushes"), 5);
        assert_eq!(m.get("alloc.bgsync.flush_failures"), 1);
        assert_eq!(m.get("alloc.bgsync.watermark_hits"), 3);
        assert_eq!(m.get("alloc.bgsync.interval_fires"), 1);
        assert_eq!(m.get("alloc.bgsync.writer_stalls"), 2);
        assert_eq!(m.get("alloc.bgsync.writer_stall_micros"), 750);
        assert_eq!(m.get("alloc.bgsync.watermark_bytes"), 4 << 20);
        assert_eq!(m.get("alloc.bgsync.pipeline_depth"), 2);
        assert_eq!(m.get("alloc.bgsync.pipeline_peak_in_flight"), 2);
        assert_eq!(m.get("alloc.bgsync.adaptive_watermark_bytes"), 9 << 20);
        assert_eq!(m.get("alloc.bgsync.measured_bandwidth_bps"), 3_000_000_000);
        assert_eq!(m.get("alloc.bgsync.epochs_committed"), 4);
    }

    #[test]
    fn attach_bridge_exports_reader_counters() {
        let m = Metrics::new();
        let s = AttachStats {
            attach_micros: 850,
            refreshes: 2,
            chunks_overlaid: 12,
            side_copies_created: 9,
            side_copies_reused: 3,
            staleness_epochs: 0,
        };
        record_attach_stats(&m, &s);
        assert_eq!(m.get("alloc.attach.count"), 1);
        assert_eq!(m.get("alloc.attach.micros"), 850);
        assert_eq!(m.get("alloc.attach.refreshes"), 2);
        assert_eq!(m.get("alloc.attach.chunks_overlaid"), 12);
        assert_eq!(m.get("alloc.attach.side_copies_created"), 9);
        assert_eq!(m.get("alloc.attach.side_copies_reused"), 3);
        assert_eq!(m.get("alloc.attach.staleness_epochs"), 0);
    }

    #[test]
    fn oplog_bridge_exports_log_counters() {
        let m = Metrics::new();
        let s = OpLogStats {
            appended: 120,
            committed: 118,
            forced_syncs: 1,
            forced_sync_errors: 1,
            recovered_forward: 2,
            recovered_rollback: 1,
            recovered_adopted: 3,
            recovered_released: 2,
            recovery_anomalies: 0,
            validate_records: 40,
        };
        record_oplog_stats(&m, &s);
        assert_eq!(m.get("alloc.oplog.appended"), 120);
        assert_eq!(m.get("alloc.oplog.committed"), 118);
        assert_eq!(m.get("alloc.oplog.forced_syncs"), 1);
        assert_eq!(m.get("alloc.oplog.forced_sync_errors"), 1);
        assert_eq!(m.get("alloc.oplog.recovered_forward"), 2);
        assert_eq!(m.get("alloc.oplog.recovered_rollback"), 1);
        assert_eq!(m.get("alloc.oplog.recovered_adopted"), 3);
        assert_eq!(m.get("alloc.oplog.recovered_released"), 2);
        assert_eq!(m.get("alloc.oplog.recovery_anomalies"), 0);
        assert_eq!(m.get("alloc.oplog.validate_records"), 40);
    }

    #[test]
    fn health_bridge_exports_fault_counters_and_degraded_gauge() {
        let m = Metrics::new();
        let s = HealthStats {
            transient_failures: 4,
            permanent_failures: 1,
            extend_rollbacks: 2,
            degraded: true,
            degraded_reason: Some("permanent backend failure: io".into()),
        };
        record_health_stats(&m, &s);
        assert_eq!(m.get("alloc.faults.transient_failures"), 4);
        assert_eq!(m.get("alloc.faults.permanent_failures"), 1);
        assert_eq!(m.get("alloc.faults.extend_rollbacks"), 2);
        assert_eq!(m.get("alloc.health.degraded"), 1);
        // a healthy manager adds a zero gauge
        record_health_stats(&m, &HealthStats::default());
        assert_eq!(m.get("alloc.health.degraded"), 1);
        assert_eq!(m.get("alloc.faults.transient_failures"), 4);
    }

    #[test]
    fn concurrent_adds() {
        let m = Metrics::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        m.add("n", 1);
                    }
                });
            }
        });
        assert_eq!(m.get("n"), 4000);
    }

    /// Many threads hammering a mix of pre-existing and fresh keys with
    /// adds, gauge sets, timer adds, and concurrent reads: the RwLock
    /// fast path must never lose an update or deadlock against the
    /// write-lock insert path.
    #[test]
    fn many_thread_mixed_updates_smoke() {
        const THREADS: usize = 16;
        const ITERS: u64 = 2000;
        let m = Metrics::new();
        m.add("hot", 0); // pre-existing: pure shared-lock traffic
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                s.spawn(|| {
                    for i in 0..ITERS {
                        m.add("hot", 1);
                        // 8 keys created racily across all threads
                        m.add(&format!("key{}", i % 8), 1);
                        m.set("gauge", i);
                        m.add_time("phase", 3);
                        if i % 64 == 0 {
                            let _ = m.get("hot");
                            let _ = m.snapshot();
                        }
                    }
                });
            }
        });
        assert_eq!(m.get("hot"), THREADS as u64 * ITERS);
        for k in 0..8 {
            assert_eq!(m.get(&format!("key{k}")), THREADS as u64 * ITERS / 8);
        }
        assert_eq!(m.get("gauge"), ITERS - 1, "last set wins (all threads end at the same value)");
        assert_eq!(
            (m.seconds("phase") * 1e9).round() as u64,
            THREADS as u64 * ITERS * 3,
            "timer adds are not lost"
        );
    }

    #[test]
    fn latency_bridge_sets_quantile_gauges() {
        use crate::telemetry::Telemetry;
        let m = Metrics::new();
        let t = Telemetry::new(1, 1);
        for ns in [100u64, 200, 300, 400, 50_000] {
            t.record_ns(Op::AllocSmall, ns);
        }
        record_latency_stats(&m, &t.snapshot());
        assert_eq!(m.get("alloc.lat.alloc_small.count"), 5);
        assert!(m.get("alloc.lat.alloc_small.p50") >= 200);
        assert!(m.get("alloc.lat.alloc_small.p999") >= 50_000);
        // no samples → no keys (not a bogus zero row)
        assert_eq!(m.get("alloc.lat.attach.count"), 0);
        assert!(!m.snapshot().0.contains_key("alloc.lat.attach.p99"));
        // re-recording overwrites in place (gauge semantics)
        record_latency_stats(&m, &t.snapshot());
        assert_eq!(m.get("alloc.lat.alloc_small.count"), 5);
    }

    /// Normalize an emitted key to its catalogue form: shard indices →
    /// `shard<N>`, latency op names → `<op>`.
    fn normalize(k: &str) -> String {
        if let Some(rest) = k.strip_prefix("alloc.lat.") {
            if let Some(dot) = rest.rfind('.') {
                return format!("alloc.lat.<op>.{}", &rest[dot + 1..]);
            }
        }
        if let Some(pos) = k.find(".shard") {
            let rest = &k[pos + ".shard".len()..];
            let digits = rest.chars().take_while(|c| c.is_ascii_digit()).count();
            if digits > 0 {
                return format!("{}.shard<N>{}", &k[..pos], &rest[digits..]);
            }
        }
        k.to_string()
    }

    /// The golden key set: every key the bridges emit is catalogued in
    /// `docs/METRICS.md`, and every catalogued `alloc.*` key is
    /// producible by a bridge. Renaming or adding a metric without
    /// updating the catalogue fails here.
    #[test]
    fn golden_key_set_matches_docs_catalogue() {
        use crate::alloc::manager::{PlacementSource, ShardPlacement};
        use crate::telemetry::Telemetry;
        use std::collections::BTreeSet;

        const DOC: &str = include_str!("../../../docs/METRICS.md");
        let catalogued: BTreeSet<String> = DOC
            .lines()
            .filter_map(|line| {
                let rest = line.strip_prefix("| `")?;
                let end = rest.find('`')?;
                Some(rest[..end].to_string())
            })
            .filter(|k| k.starts_with("alloc."))
            .collect();
        assert!(catalogued.len() > 50, "catalogue parsed ({} keys)", catalogued.len());

        // Drive every bridge once; values are irrelevant, keys are not.
        let m = Metrics::new();
        record_alloc_stats(
            &m,
            &StatsSnapshot {
                allocs: 1,
                deallocs: 1,
                cache_hits: 1,
                fast_claims: 1,
                fresh_chunks: 1,
                freed_chunks: 1,
                large_allocs: 1,
            },
            &[ShardStatsSnapshot {
                shard: 0,
                fast_claims: 1,
                fresh_chunks: 1,
                freed_chunks: 1,
                remote_frees: 1,
                remote_drained: 1,
                exclusive_acquires: 1,
                first_touch_chunks: 1,
                bound_chunks: 1,
            }],
        );
        record_placement(
            &m,
            &PlacementReport {
                per_shard: vec![ShardPlacement { shard: 0, pages: 1, ..Default::default() }],
                large_pages: 1,
                free_pages: 1,
                total_pages: 2,
                source: PlacementSource::Recorded,
            },
        );
        record_sync_stats(&m, &SyncStats { syncs: 1, dirty_sections: 1, ..Default::default() });
        record_bg_sync_stats(
            &m,
            &BgSyncStats {
                flushes: 1,
                flush_failures: 1,
                watermark_triggers: 1,
                ceiling_triggers: 1,
                interval_triggers: 1,
                explicit_requests: 1,
                section_bytes_flushed: 1,
                data_bytes_flushed: 1,
                writer_stalls: 1,
                writer_stall_micros: 1,
                watermark_bytes: 1,
                ceiling_bytes: 1,
                pipeline_depth: 1,
                pipeline_peak_in_flight: 1,
                adaptive_watermark_bytes: 1,
                measured_bandwidth_bps: 1,
                epochs_committed: 1,
                engine_running: true,
                engine_dead: false,
            },
        );
        record_oplog_stats(
            &m,
            &OpLogStats {
                appended: 1,
                committed: 1,
                forced_syncs: 1,
                forced_sync_errors: 1,
                recovered_forward: 1,
                recovered_rollback: 1,
                recovered_adopted: 1,
                recovered_released: 1,
                recovery_anomalies: 1,
                validate_records: 1,
            },
        );
        record_health_stats(
            &m,
            &HealthStats {
                transient_failures: 1,
                permanent_failures: 1,
                extend_rollbacks: 1,
                degraded: false,
                degraded_reason: None,
            },
        );
        record_attach_stats(
            &m,
            &AttachStats {
                attach_micros: 1,
                refreshes: 1,
                chunks_overlaid: 1,
                side_copies_created: 1,
                side_copies_reused: 1,
                staleness_epochs: 1,
            },
        );
        let t = Telemetry::new(1, 1);
        for op in Op::ALL {
            t.record_ns(op, 1_000);
        }
        record_latency_stats(&m, &t.snapshot());

        let emitted: BTreeSet<String> = m.snapshot().0.keys().map(|k| normalize(k.as_str())).collect();
        for k in &emitted {
            assert!(catalogued.contains(k), "emitted key `{k}` missing from docs/METRICS.md");
        }
        for k in &catalogued {
            assert!(emitted.contains(k), "catalogued key `{k}` no longer produced by any bridge");
        }
    }
}

//! Lightweight metrics: counters and phase timers for the pipeline and
//! the experiment harness, plus the allocator-counter bridge
//! ([`record_alloc_stats`]) that folds the manager's aggregate totals and
//! per-shard contention counters into a metrics set.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::alloc::bg_sync::BgSyncStats;
use crate::alloc::bin_dir::ShardStatsSnapshot;
use crate::alloc::manager::{AttachStats, HealthStats, PlacementReport, StatsSnapshot, SyncStats};
use crate::containers::oplog::OpLogStats;

/// A named set of monotonically increasing counters plus accumulated
/// phase durations. Cheap to share behind an `Arc`.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, AtomicU64>>,
    timers_ns: Mutex<BTreeMap<String, AtomicU64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, name: &str, v: u64) {
        let mut m = self.counters.lock().unwrap();
        m.entry(name.to_string()).or_default().fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Time a closure, accumulating into phase `name`.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let r = f();
        self.add_time(name, t0.elapsed().as_nanos() as u64);
        r
    }

    pub fn add_time(&self, name: &str, ns: u64) {
        let mut m = self.timers_ns.lock().unwrap();
        m.entry(name.to_string()).or_default().fetch_add(ns, Ordering::Relaxed);
    }

    pub fn seconds(&self, name: &str) -> f64 {
        self.timers_ns
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed) as f64 / 1e9)
            .unwrap_or(0.0)
    }

    /// Snapshot all values for reporting.
    pub fn snapshot(&self) -> (BTreeMap<String, u64>, BTreeMap<String, f64>) {
        let c = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let t = self
            .timers_ns
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed) as f64 / 1e9))
            .collect();
        (c, t)
    }
}

/// Fold an allocator snapshot into `m`: manager-wide totals under the
/// pre-sharding `alloc.*` names (backward compatible — the shard count
/// never changes these keys or their meaning) and per-shard contention
/// counters under `alloc.shard<N>.*`. Counters are monotonic adds: call
/// once per snapshot, or feed deltas.
pub fn record_alloc_stats(m: &Metrics, totals: &StatsSnapshot, shards: &[ShardStatsSnapshot]) {
    m.add("alloc.allocs", totals.allocs);
    m.add("alloc.deallocs", totals.deallocs);
    m.add("alloc.cache_hits", totals.cache_hits);
    m.add("alloc.fast_claims", totals.fast_claims);
    m.add("alloc.fresh_chunks", totals.fresh_chunks);
    m.add("alloc.freed_chunks", totals.freed_chunks);
    m.add("alloc.large_allocs", totals.large_allocs);
    for s in shards {
        let k = |name: &str| format!("alloc.shard{}.{name}", s.shard);
        m.add(&k("fast_claims"), s.fast_claims);
        m.add(&k("fresh_chunks"), s.fresh_chunks);
        m.add(&k("freed_chunks"), s.freed_chunks);
        m.add(&k("remote_frees"), s.remote_frees);
        m.add(&k("remote_drained"), s.remote_drained);
        m.add(&k("exclusive_acquires"), s.exclusive_acquires);
        m.add(&k("first_touch_chunks"), s.first_touch_chunks);
        m.add(&k("bound_chunks"), s.bound_chunks);
    }
}

/// Fold a NUMA placement report into `m`: per-shard node-locality
/// counters under `alloc.shard<N>.node_local_pages` (plus
/// remote/unknown/total) and the segment-wide buckets under
/// `alloc.placement.*`. Counters are monotonic adds: call once per
/// report, or feed deltas.
pub fn record_placement(m: &Metrics, r: &PlacementReport) {
    m.add("alloc.placement.total_pages", r.total_pages);
    m.add("alloc.placement.free_pages", r.free_pages);
    m.add("alloc.placement.large_pages", r.large_pages);
    for s in &r.per_shard {
        let k = |name: &str| format!("alloc.shard{}.{name}", s.shard);
        m.add(&k("node_local_pages"), s.node_local_pages);
        m.add(&k("remote_pages"), s.remote_pages);
        m.add(&k("unknown_pages"), s.unknown_pages);
        m.add(&k("placement_pages"), s.pages);
    }
}

/// Fold one sync's [`SyncStats`] into `m` under `alloc.sync.*`: per-sync
/// gauges are added as deltas (the struct's last-sync fields describe
/// exactly one sync), so calling once after every `sync()` accumulates
/// totals. `alloc.sync.count` / `alloc.sync.manifest_commits` count
/// invocations and real commits (a no-op sync adds zero everywhere
/// else).
pub fn record_sync_stats(m: &Metrics, s: &SyncStats) {
    m.add("alloc.sync.count", 1);
    // the last sync committed a manifest iff it had dirty sections
    m.add("alloc.sync.manifest_commits", u64::from(s.dirty_sections > 0));
    m.add("alloc.sync.dirty_sections", s.dirty_sections);
    m.add("alloc.sync.section_bytes", s.section_bytes_written);
    m.add("alloc.sync.data_chunks", s.data_chunks_flushed);
    m.add("alloc.sync.data_bytes", s.data_bytes_flushed);
    m.add("alloc.sync.flush_micros", s.flush_micros);
    m.add("alloc.sync.sim_flush_micros", s.sim_flush_micros);
    m.add("alloc.sync.cache_slots_preserved", s.cache_slots_preserved);
}

/// Fold a background-engine snapshot into `m` under `alloc.bgsync.*`.
/// [`BgSyncStats`] counters are cumulative over the engine's lifetime
/// (unlike the per-sync [`SyncStats`] gauges), so call this once per
/// manager at report time — or feed deltas when sampling repeatedly.
pub fn record_bg_sync_stats(m: &Metrics, s: &BgSyncStats) {
    m.add("alloc.bgsync.flushes", s.flushes);
    m.add("alloc.bgsync.flush_failures", s.flush_failures);
    m.add("alloc.bgsync.watermark_hits", s.watermark_triggers);
    m.add("alloc.bgsync.ceiling_hits", s.ceiling_triggers);
    m.add("alloc.bgsync.interval_fires", s.interval_triggers);
    m.add("alloc.bgsync.explicit_requests", s.explicit_requests);
    m.add("alloc.bgsync.section_bytes", s.section_bytes_flushed);
    m.add("alloc.bgsync.data_bytes", s.data_bytes_flushed);
    m.add("alloc.bgsync.writer_stalls", s.writer_stalls);
    m.add("alloc.bgsync.writer_stall_micros", s.writer_stall_micros);
    m.add("alloc.bgsync.watermark_bytes", s.watermark_bytes);
    m.add("alloc.bgsync.ceiling_bytes", s.ceiling_bytes);
    m.add("alloc.bgsync.pipeline_depth", s.pipeline_depth);
    m.add("alloc.bgsync.pipeline_peak_in_flight", s.pipeline_peak_in_flight);
    m.add("alloc.bgsync.adaptive_watermark_bytes", s.adaptive_watermark_bytes);
    m.add("alloc.bgsync.measured_bandwidth_bps", s.measured_bandwidth_bps);
    m.add("alloc.bgsync.epochs_committed", s.epochs_committed);
}

/// Fold a manager's container op-log counters into `m` under
/// `alloc.oplog.*`. [`OpLogStats`] counters are cumulative over the
/// manager's lifetime (recovery counters are set once at open), so call
/// this once per manager at report time — or feed deltas when sampling
/// repeatedly.
pub fn record_oplog_stats(m: &Metrics, s: &OpLogStats) {
    m.add("alloc.oplog.appended", s.appended);
    m.add("alloc.oplog.committed", s.committed);
    m.add("alloc.oplog.forced_syncs", s.forced_syncs);
    m.add("alloc.oplog.forced_sync_errors", s.forced_sync_errors);
    m.add("alloc.oplog.recovered_forward", s.recovered_forward);
    m.add("alloc.oplog.recovered_rollback", s.recovered_rollback);
    m.add("alloc.oplog.recovered_adopted", s.recovered_adopted);
    m.add("alloc.oplog.recovered_released", s.recovered_released);
    m.add("alloc.oplog.recovery_anomalies", s.recovery_anomalies);
    m.add("alloc.oplog.validate_records", s.validate_records);
}

/// Fold a manager's failure-health snapshot into `m`: classified flush
/// failures and allocation-path rollbacks under `alloc.faults.*`, and
/// the degraded flag as the 0/1 gauge `alloc.health.degraded`.
/// [`HealthStats`] counters are cumulative over the manager's lifetime,
/// so call this once per manager at report time — or feed deltas when
/// sampling repeatedly.
pub fn record_health_stats(m: &Metrics, s: &HealthStats) {
    m.add("alloc.faults.transient_failures", s.transient_failures);
    m.add("alloc.faults.permanent_failures", s.permanent_failures);
    m.add("alloc.faults.extend_rollbacks", s.extend_rollbacks);
    m.add("alloc.health.degraded", u64::from(s.degraded));
}

/// Fold one reader's [`AttachStats`] into `m` under `alloc.attach.*`.
/// The struct is cumulative over one attach's lifetime (created /
/// reused / refreshes grow monotonically; `staleness_epochs` is the
/// value at the last attach/refresh decision), so call this once per
/// reader at report time — or feed deltas when sampling repeatedly.
pub fn record_attach_stats(m: &Metrics, s: &AttachStats) {
    m.add("alloc.attach.count", 1);
    m.add("alloc.attach.micros", s.attach_micros);
    m.add("alloc.attach.refreshes", s.refreshes);
    m.add("alloc.attach.chunks_overlaid", s.chunks_overlaid);
    m.add("alloc.attach.side_copies_created", s.side_copies_created);
    m.add("alloc.attach.side_copies_reused", s.side_copies_reused);
    m.add("alloc.attach.staleness_epochs", s.staleness_epochs);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_timers() {
        let m = Metrics::new();
        m.add("edges", 5);
        m.add("edges", 7);
        assert_eq!(m.get("edges"), 12);
        assert_eq!(m.get("missing"), 0);
        let v = m.time("phase", || 21 * 2);
        assert_eq!(v, 42);
        assert!(m.seconds("phase") >= 0.0);
        let (c, t) = m.snapshot();
        assert_eq!(c["edges"], 12);
        assert!(t.contains_key("phase"));
    }

    #[test]
    fn alloc_stats_bridge_keeps_totals_backward_compatible() {
        let m = Metrics::new();
        let totals = StatsSnapshot {
            allocs: 10,
            deallocs: 4,
            cache_hits: 3,
            fast_claims: 7,
            fresh_chunks: 2,
            freed_chunks: 1,
            large_allocs: 0,
        };
        let shards = vec![
            ShardStatsSnapshot { shard: 0, fast_claims: 5, fresh_chunks: 1, ..Default::default() },
            ShardStatsSnapshot {
                shard: 1,
                fast_claims: 2,
                fresh_chunks: 1,
                freed_chunks: 1,
                remote_frees: 6,
                remote_drained: 6,
                exclusive_acquires: 3,
                ..Default::default()
            },
        ];
        record_alloc_stats(&m, &totals, &shards);
        // pre-sharding keys carry the aggregates
        assert_eq!(m.get("alloc.allocs"), 10);
        assert_eq!(m.get("alloc.fast_claims"), 7);
        // per-shard contention counters sum to the totals
        assert_eq!(
            m.get("alloc.shard0.fast_claims") + m.get("alloc.shard1.fast_claims"),
            m.get("alloc.fast_claims")
        );
        assert_eq!(m.get("alloc.shard1.remote_frees"), 6);
        assert_eq!(m.get("alloc.shard1.exclusive_acquires"), 3);
    }

    #[test]
    fn placement_bridge_exports_node_locality() {
        use crate::alloc::manager::{PlacementSource, ShardPlacement};
        let m = Metrics::new();
        let report = PlacementReport {
            per_shard: vec![
                ShardPlacement {
                    shard: 0,
                    node: 0,
                    pages: 128,
                    node_local_pages: 126,
                    remote_pages: 2,
                    unknown_pages: 0,
                },
                ShardPlacement {
                    shard: 1,
                    node: 1,
                    pages: 64,
                    node_local_pages: 64,
                    ..Default::default()
                },
            ],
            large_pages: 32,
            free_pages: 16,
            total_pages: 240,
            source: PlacementSource::Recorded,
        };
        assert_eq!(report.accounted_pages(), 240);
        record_placement(&m, &report);
        assert_eq!(m.get("alloc.shard0.node_local_pages"), 126);
        assert_eq!(m.get("alloc.shard0.remote_pages"), 2);
        assert_eq!(m.get("alloc.shard1.node_local_pages"), 64);
        assert_eq!(m.get("alloc.shard1.placement_pages"), 64);
        assert_eq!(m.get("alloc.placement.total_pages"), 240);
        assert_eq!(m.get("alloc.placement.large_pages"), 32);
    }

    #[test]
    fn sync_bridge_accumulates_per_sync_deltas() {
        let m = Metrics::new();
        // a full first sync…
        record_sync_stats(
            &m,
            &SyncStats {
                syncs: 1,
                manifest_commits: 1,
                dirty_sections: 9,
                total_sections: 9,
                section_bytes_written: 4096,
                data_chunks_flushed: 32,
                data_bytes_flushed: 32 << 16,
                flush_micros: 1500,
                sim_flush_micros: 900,
                cache_slots_preserved: 12,
            },
        );
        // …then a no-op sync adds only the invocation count
        record_sync_stats(
            &m,
            &SyncStats { syncs: 2, manifest_commits: 1, total_sections: 9, ..Default::default() },
        );
        assert_eq!(m.get("alloc.sync.count"), 2);
        assert_eq!(m.get("alloc.sync.manifest_commits"), 1);
        assert_eq!(m.get("alloc.sync.dirty_sections"), 9);
        assert_eq!(m.get("alloc.sync.section_bytes"), 4096);
        assert_eq!(m.get("alloc.sync.data_chunks"), 32);
        assert_eq!(m.get("alloc.sync.flush_micros"), 1500);
        assert_eq!(m.get("alloc.sync.sim_flush_micros"), 900);
        assert_eq!(m.get("alloc.sync.cache_slots_preserved"), 12);
    }

    #[test]
    fn bg_sync_bridge_exports_engine_counters() {
        let m = Metrics::new();
        let s = BgSyncStats {
            flushes: 5,
            flush_failures: 1,
            watermark_triggers: 3,
            ceiling_triggers: 0,
            interval_triggers: 1,
            explicit_requests: 1,
            section_bytes_flushed: 2048,
            data_bytes_flushed: 1 << 20,
            writer_stalls: 2,
            writer_stall_micros: 750,
            watermark_bytes: 4 << 20,
            ceiling_bytes: 16 << 20,
            pipeline_depth: 2,
            pipeline_peak_in_flight: 2,
            adaptive_watermark_bytes: 9 << 20,
            measured_bandwidth_bps: 3_000_000_000,
            epochs_committed: 4,
            engine_running: true,
            engine_dead: false,
        };
        record_bg_sync_stats(&m, &s);
        assert_eq!(m.get("alloc.bgsync.flushes"), 5);
        assert_eq!(m.get("alloc.bgsync.flush_failures"), 1);
        assert_eq!(m.get("alloc.bgsync.watermark_hits"), 3);
        assert_eq!(m.get("alloc.bgsync.interval_fires"), 1);
        assert_eq!(m.get("alloc.bgsync.writer_stalls"), 2);
        assert_eq!(m.get("alloc.bgsync.writer_stall_micros"), 750);
        assert_eq!(m.get("alloc.bgsync.watermark_bytes"), 4 << 20);
        assert_eq!(m.get("alloc.bgsync.pipeline_depth"), 2);
        assert_eq!(m.get("alloc.bgsync.pipeline_peak_in_flight"), 2);
        assert_eq!(m.get("alloc.bgsync.adaptive_watermark_bytes"), 9 << 20);
        assert_eq!(m.get("alloc.bgsync.measured_bandwidth_bps"), 3_000_000_000);
        assert_eq!(m.get("alloc.bgsync.epochs_committed"), 4);
    }

    #[test]
    fn attach_bridge_exports_reader_counters() {
        let m = Metrics::new();
        let s = AttachStats {
            attach_micros: 850,
            refreshes: 2,
            chunks_overlaid: 12,
            side_copies_created: 9,
            side_copies_reused: 3,
            staleness_epochs: 0,
        };
        record_attach_stats(&m, &s);
        assert_eq!(m.get("alloc.attach.count"), 1);
        assert_eq!(m.get("alloc.attach.micros"), 850);
        assert_eq!(m.get("alloc.attach.refreshes"), 2);
        assert_eq!(m.get("alloc.attach.chunks_overlaid"), 12);
        assert_eq!(m.get("alloc.attach.side_copies_created"), 9);
        assert_eq!(m.get("alloc.attach.side_copies_reused"), 3);
        assert_eq!(m.get("alloc.attach.staleness_epochs"), 0);
    }

    #[test]
    fn oplog_bridge_exports_log_counters() {
        let m = Metrics::new();
        let s = OpLogStats {
            appended: 120,
            committed: 118,
            forced_syncs: 1,
            forced_sync_errors: 1,
            recovered_forward: 2,
            recovered_rollback: 1,
            recovered_adopted: 3,
            recovered_released: 2,
            recovery_anomalies: 0,
            validate_records: 40,
        };
        record_oplog_stats(&m, &s);
        assert_eq!(m.get("alloc.oplog.appended"), 120);
        assert_eq!(m.get("alloc.oplog.committed"), 118);
        assert_eq!(m.get("alloc.oplog.forced_syncs"), 1);
        assert_eq!(m.get("alloc.oplog.forced_sync_errors"), 1);
        assert_eq!(m.get("alloc.oplog.recovered_forward"), 2);
        assert_eq!(m.get("alloc.oplog.recovered_rollback"), 1);
        assert_eq!(m.get("alloc.oplog.recovered_adopted"), 3);
        assert_eq!(m.get("alloc.oplog.recovered_released"), 2);
        assert_eq!(m.get("alloc.oplog.recovery_anomalies"), 0);
        assert_eq!(m.get("alloc.oplog.validate_records"), 40);
    }

    #[test]
    fn health_bridge_exports_fault_counters_and_degraded_gauge() {
        let m = Metrics::new();
        let s = HealthStats {
            transient_failures: 4,
            permanent_failures: 1,
            extend_rollbacks: 2,
            degraded: true,
            degraded_reason: Some("permanent backend failure: io".into()),
        };
        record_health_stats(&m, &s);
        assert_eq!(m.get("alloc.faults.transient_failures"), 4);
        assert_eq!(m.get("alloc.faults.permanent_failures"), 1);
        assert_eq!(m.get("alloc.faults.extend_rollbacks"), 2);
        assert_eq!(m.get("alloc.health.degraded"), 1);
        // a healthy manager adds a zero gauge
        record_health_stats(&m, &HealthStats::default());
        assert_eq!(m.get("alloc.health.degraded"), 1);
        assert_eq!(m.get("alloc.faults.transient_failures"), 4);
    }

    #[test]
    fn concurrent_adds() {
        let m = Metrics::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        m.add("n", 1);
                    }
                });
            }
        });
        assert_eq!(m.get("n"), 4000);
    }
}

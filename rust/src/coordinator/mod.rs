//! L3 coordinator: the streaming graph-ingestion pipeline, metrics, and
//! the CLI entry point.

pub mod cli;
pub mod metrics;
pub mod pipeline;

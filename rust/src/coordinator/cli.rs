//! Hand-rolled CLI (the offline image carries no clap). The launcher for
//! the whole system: datastore lifecycle, streaming ingestion, and
//! PJRT-backed analytics.

use anyhow::{anyhow, bail, Context, Result};

use crate::alloc::{ManagerOptions, MetallManager};
use crate::containers::BankedAdjacency;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pipeline::{ingest, PipelineConfig};
use crate::graph::ell_cache::{self, EllCache};
use crate::graph::rmat::RmatGenerator;
use crate::runtime::engine::AnalyticsEngine;
use crate::util::human;

const HELP: &str = "\
metall — persistent-memory data analytics (Metall reproduction)

USAGE:
    metall <SUBCOMMAND> [OPTIONS]

SUBCOMMANDS:
    create    --store <dir>                          create an empty datastore
    ingest    --store <dir> --scale <s> [--threads n] [--edge-factor 16]
              [--banks 1024] [--batch 4096] [--seed 0] [--append]
                                                     R-MAT stream → banked adjacency list
    inspect   --store <dir>                          named objects + usage stats
    snapshot  --store <dir> --to <dir>               reflink/copy snapshot
    sync      --store <dir> [--watermark-mb n] [--interval-ms n]
              [--pipeline <depth>] [--netfs <profile>]
                                                     run a background sync epoch and print
                                                     the alloc.sync.* / alloc.bgsync.* metrics
                                                     (--netfs charges a simulated backend;
                                                     unknown profiles fail fast listing names)
    analyze   --store <dir> --algo <pagerank|bfs> [--artifacts artifacts]
              [--iters 50] [--source 0] [--top 5]    run analytics via the PJRT engine
                                                     (uses/refreshes the persistent ELL cache)
    attach    --store <dir> [--readers 2] [--rounds 3] [--scale 12]
              [--out BENCH_attach.json]              multi-process snapshot-isolation bench:
                                                     N reader processes attach to pinned
                                                     epochs and run GBTL BFS while this
                                                     process keeps ingesting + flushing
    stats     --store <dir> [--format prom|json] [--watch] [--probe-ops 256]
                                                     export counters, latency quantiles
                                                     (p50/p90/p99/p999), and the flight-
                                                     recorder tail as Prometheus text
                                                     exposition or JSON; probes the store
                                                     with real ops when it can be opened
                                                     read-write (--probe-ops 0 disables)
    trace     --store <dir> [--tail 32]              render the newest flight-recorder
                                                     dump under <store>/diag/ (survives
                                                     kill -9: the ring is an mmap'd file)
    doctor    --store <dir>                          validate datastore integrity (prints
                                                     the flight-recorder tail when a diag
                                                     dump or WOUNDED breadcrumb is present)
    version | help
";

fn req<'a>(args: &'a crate::bench_util::BenchArgs, key: &str) -> Result<&'a str> {
    args.get(key).ok_or_else(|| anyhow!("missing required --{key}\n\n{HELP}"))
}

/// Run the CLI; returns the process exit code.
pub fn run(argv: &[String]) -> Result<i32> {
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    // BenchArgs::parse reads process args; re-parse from argv[1..] instead
    let args = parse_args(&argv[1..]);
    match cmd {
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(0)
        }
        "version" | "--version" => {
            println!("metall-rs {}", env!("CARGO_PKG_VERSION"));
            Ok(0)
        }
        "create" => {
            let store = req(&args, "store")?;
            let mgr = MetallManager::create(store).context("create datastore")?;
            mgr.close()?;
            println!("created datastore at {store}");
            Ok(0)
        }
        "ingest" => {
            let store = req(&args, "store")?;
            let scale = args.get_usize("scale", 16) as u32;
            let threads = args.get_usize("threads", 4);
            let ef = args.get_usize("edge-factor", 16);
            let banks = args.get_usize("banks", 1024);
            let batch = args.get_usize("batch", 4096);
            let seed = args.get_usize("seed", 0) as u64;
            let append = args.has("append");

            let mgr = if append {
                MetallManager::open(store).context("open datastore")?
            } else {
                MetallManager::create(store).context("create datastore")?
            };
            let graph = match mgr.find::<u64>("graph")? {
                Some(off) => BankedAdjacency::open(&mgr, mgr.read(off)),
                None => {
                    let g = BankedAdjacency::create(&mgr, banks)?;
                    mgr.construct::<u64>("graph", g.offset())?;
                    g
                }
            };
            let gen = RmatGenerator::graph500(scale, ef).seed(seed);
            let metrics = Metrics::new();
            let cfg = PipelineConfig {
                workers: threads,
                batch_size: batch,
                queue_depth: 16,
                nbanks: banks,
            };
            println!(
                "ingesting R-MAT SCALE {scale} (|V|=2^{scale}, {} undirected edges) with {threads} workers…",
                gen.num_edges()
            );
            let rep = ingest(&mgr, &graph, gen.generate().into_iter(), &cfg, true, &metrics)?;
            println!(
                "ingested {} edges in {} ({})",
                rep.edges,
                human::duration(rep.ingest_secs),
                human::rate(rep.edges_per_sec)
            );
            mgr.close()?;
            Ok(0)
        }
        "inspect" => {
            let store = req(&args, "store")?;
            let mgr = MetallManager::open_read_only(store).context("open datastore")?;
            println!("datastore: {store}");
            println!("chunk size: {}", human::bytes(mgr.chunk_size() as u64));
            println!("segment used: {}", human::bytes(mgr.used_segment_bytes() as u64));
            println!(
                "file blocks allocated: {}",
                human::bytes(mgr.segment().allocated_file_blocks()? * 512)
            );
            println!("named objects ({}):", mgr.num_named());
            for (name, off, size) in mgr.named_list() {
                println!("  {name:<24} offset={off:<12} size={size}");
            }
            if let Some(off) = mgr.find::<u64>("graph")? {
                let g = BankedAdjacency::open(&mgr, mgr.read(off));
                println!(
                    "graph: {} vertices, {} directed edges, {} banks",
                    g.num_vertices(&mgr),
                    g.num_edges(&mgr),
                    g.nbanks()
                );
            }
            Ok(0)
        }
        "snapshot" => {
            let store = req(&args, "store")?;
            let to = req(&args, "to")?;
            let mgr = MetallManager::open(store).context("open datastore")?;
            let method = mgr.snapshot(to)?;
            mgr.close()?;
            println!("snapshot {store} -> {to} ({method:?})");
            Ok(0)
        }
        "sync" => {
            let store = req(&args, "store")?;
            // Validate the profile name before touching the datastore so a
            // typo fails fast with the list of known backends.
            let netfs_profile = match args.get("netfs") {
                Some(name) => {
                    crate::storage::netfs::profile_by_name_strict(name)
                        .map_err(|e| anyhow!("{e}"))?;
                    Some(name.to_string())
                }
                None => None,
            };
            let o = ManagerOptions {
                sync_watermark_bytes: args.get_usize("watermark-mb", 0) << 20,
                sync_interval_ms: args.get_usize("interval-ms", 0) as u64,
                sync_pipeline_depth: args.get_usize("pipeline", 0),
                netfs_profile,
                ..Default::default()
            };
            let mgr = MetallManager::open_with(store, o, false, false).context("open datastore")?;
            let ticket = mgr.sync_async()?;
            let epoch = ticket.generation();
            ticket.wait()?;
            println!("{store}: background flush epoch {epoch} durably committed");
            let metrics = Metrics::new();
            crate::coordinator::metrics::record_sync_stats(&metrics, &mgr.sync_stats());
            crate::coordinator::metrics::record_bg_sync_stats(&metrics, &mgr.bg_sync_stats());
            let (counters, _) = metrics.snapshot();
            for (k, v) in counters {
                println!("  {k:<36} {v}");
            }
            mgr.close()?;
            Ok(0)
        }
        "analyze" => {
            let store = req(&args, "store")?;
            let algo = req(&args, "algo")?.to_string();
            let artifacts = args.get("artifacts").unwrap_or("artifacts").to_string();
            let iters = args.get_usize("iters", 50);
            let source = args.get_usize("source", 0);
            let top = args.get_usize("top", 5);

            // Prefer the persistent ELL cache (built by a previous
            // analyze/ingest); rebuild and persist when stale/missing.
            let (ell, n) = {
                let ro = MetallManager::open_read_only(store).context("open datastore")?;
                let off = ro
                    .find::<u64>("graph")?
                    .ok_or_else(|| anyhow!("no graph in datastore (run ingest first)"))?;
                let graph = BankedAdjacency::open(&ro, ro.read(off));
                let cached = match ro.find::<EllCache>(ell_cache::CACHE_NAME)? {
                    Some(coff) => ro.read::<EllCache>(coff).load(&ro, &graph),
                    None => None,
                };
                match cached {
                    Some(ell) => {
                        println!("using persistent ELL cache ({} fragments)", ell.f);
                        let n = ell.n;
                        (ell, n)
                    }
                    None => {
                        drop(ro); // reopen writable to refresh the cache
                        let rw = MetallManager::open(store).context("open datastore rw")?;
                        let off = rw.find::<u64>("graph")?.unwrap();
                        let graph = BankedAdjacency::open(&rw, rw.read(off));
                        println!("(re)building ELL cache…");
                        let cache = EllCache::build(&rw, &graph, 32)?;
                        if let Some(old) = rw.find::<EllCache>(ell_cache::CACHE_NAME)? {
                            rw.read::<EllCache>(old).destroy(&rw)?;
                            rw.destroy(ell_cache::CACHE_NAME)?;
                        }
                        rw.construct::<EllCache>(ell_cache::CACHE_NAME, cache)?;
                        let ell = cache.load_unchecked(&rw);
                        rw.close()?;
                        let n = ell.n;
                        (ell, n)
                    }
                }
            };
            let mgr = MetallManager::open_read_only(store)?;
            let engine = AnalyticsEngine::new(&artifacts).context("load artifacts")?;
            match algo.as_str() {
                "pagerank" => {
                    let run = engine.pagerank(&ell, iters, 1e-7).context("pagerank")?;
                    println!(
                        "pagerank: {} iters, exec {} (compile {})",
                        run.iterations,
                        human::duration(run.exec_secs),
                        human::duration(run.compile_secs)
                    );
                    let mut idx: Vec<usize> = (0..n).collect();
                    idx.sort_by(|&a, &b| run.values[b].partial_cmp(&run.values[a]).unwrap());
                    for &v in idx.iter().take(top) {
                        println!("  vertex {v:<10} rank {:.6}", run.values[v]);
                    }
                }
                "bfs" => {
                    let run = engine.bfs(&ell, source).context("bfs")?;
                    let reached = run.values.iter().filter(|&&l| l >= 0.0).count();
                    let max_l = run.values.iter().cloned().fold(0f32, f32::max);
                    println!(
                        "bfs from {source}: {} levels, {reached}/{n} reached, exec {}",
                        max_l as i64,
                        human::duration(run.exec_secs)
                    );
                }
                other => bail!("unknown --algo {other} (pagerank|bfs)"),
            }
            Ok(0)
        }
        "attach" => {
            let store = req(&args, "store")?.to_string();
            let readers = args.get_usize("readers", 2).max(1);
            let rounds = args.get_usize("rounds", 3).max(1);
            let scale = args.get_usize("scale", 12) as u32;
            let out = args.get("out").unwrap_or("BENCH_attach.json").to_string();
            run_attach_bench(&store, readers, rounds, scale, &out)
        }
        // hidden: one reader process of the attach bench (spawned by
        // `attach` via current_exe; not listed in HELP on purpose)
        "attach-reader" => {
            let store = req(&args, "store")?;
            run_attach_reader(store, args.get("ready"))
        }
        "stats" => {
            let store = req(&args, "store")?;
            let format = args.get("format").unwrap_or("prom").to_string();
            if !matches!(format.as_str(), "prom" | "json") {
                bail!("unknown --format {format} (prom|json)");
            }
            let watch = args.has("watch");
            let probe_ops = args.get_usize("probe-ops", 256);
            loop {
                let b = collect_stats(store, probe_ops)?;
                match format.as_str() {
                    "prom" => {
                        let text = crate::telemetry::export::render_prometheus(&b);
                        crate::telemetry::export::validate_prometheus(&text)
                            .map_err(|e| anyhow!("internal: invalid exposition: {e}"))?;
                        print!("{text}");
                    }
                    _ => println!("{}", crate::telemetry::export::render_json(&b)),
                }
                if !watch {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_secs(2));
                println!();
            }
            Ok(0)
        }
        "trace" => {
            let store = req(&args, "store")?;
            let tail = args.get_usize("tail", 32);
            let Some(path) = crate::telemetry::recorder::newest_dump(std::path::Path::new(store))
            else {
                println!("{store}: no flight-recorder dump under diag/");
                return Ok(1);
            };
            let dump = crate::telemetry::recorder::load(&path)
                .with_context(|| format!("parse flight dump {}", path.display()))?;
            println!(
                "flight recorder {} — pid {}, capacity {}, {} events",
                path.display(),
                dump.pid,
                dump.capacity,
                dump.events.len()
            );
            for line in crate::telemetry::recorder::render_tail(&dump, tail) {
                println!("  {line}");
            }
            Ok(0)
        }
        "doctor" => {
            let store = req(&args, "store")?;
            // The advisory WOUNDED breadcrumb is the cross-process signal
            // that a previous owner degraded to read-only after a backend
            // failure (the in-process flag dies with that owner). It is
            // advisory — the store itself recovers to its last committed
            // manifest — but worth surfacing loudly.
            let wounded_reason =
                std::fs::read_to_string(std::path::Path::new(store).join(crate::alloc::WOUNDED_MARKER))
                    .ok()
                    .map(|r| {
                        format!(
                            "previous owner wounded (degraded read-only after backend \
                             failure): {}",
                            r.trim()
                        )
                    });
            let mgr = match MetallManager::open_read_only(store) {
                Ok(mgr) => mgr,
                // A wounded store refused its CLEAN marker, so the
                // CLEAN-gated read-only open cannot audit it — report the
                // wound (and the recovery route) instead of a bare error.
                Err(e) => {
                    if let Some(w) = wounded_reason {
                        println!("WARN: {w}");
                        println!(
                            "WARN: store was not closed cleanly ({e}); reopen \
                             read-write with open_unclean() to recover to the \
                             last committed manifest"
                        );
                        // the wounded owner msync'd its flight ring on the
                        // way down — show what it saw last
                        print_flight_tail(store, 12);
                        return Ok(1);
                    }
                    return Err(e).context("open datastore");
                }
            };
            let mut report = mgr.doctor()?;
            if let Some(w) = wounded_reason {
                report.insert(0, w);
            }
            if report.is_empty() {
                let audited = mgr.oplog_stats().validate_records;
                println!("{store}: OK — management data consistent, all named \
                          objects within the mapped segment, container \
                          invariants hold ({audited} op-log records audited)");
                print_flight_tail(store, 8);
                Ok(0)
            } else {
                for finding in &report {
                    println!("WARN: {finding}");
                }
                print_flight_tail(store, 12);
                Ok(1)
            }
        }
        other => {
            eprintln!("unknown subcommand: {other}\n");
            print!("{HELP}");
            Ok(2)
        }
    }
}

/// Parse `--key value` pairs from an argv slice.
fn parse_args(argv: &[String]) -> crate::bench_util::BenchArgs {
    crate::bench_util::BenchArgs::from_slice(argv)
}

/// Print the last `tail` flight-recorder events of the newest dump under
/// `<store>/diag/`, if one exists and parses. Best-effort: diagnostics
/// of diagnostics must never turn a doctor run into an error.
fn print_flight_tail(store: &str, tail: usize) {
    use crate::telemetry::recorder;
    let Some(path) = recorder::newest_dump(std::path::Path::new(store)) else { return };
    let Ok(dump) = recorder::load(&path) else {
        println!("WARN: flight dump {} exists but does not parse", path.display());
        return;
    };
    let lines = recorder::render_tail(&dump, tail);
    if lines.is_empty() {
        return;
    }
    println!("flight recorder tail ({}, pid {}):", path.display(), dump.pid);
    for l in lines {
        println!("  {l}");
    }
}

/// Gather everything `metall stats` exports. When the store can be
/// opened read-write, a short probe (real small/large allocations, a
/// dealloc pass, one sync epoch, one reader attach) feeds the latency
/// histograms genuine samples at sample rate 1 — so a fresh store still
/// reports meaningful p99/p999 rows. Falls back to read-only (no
/// probes) when another owner holds the store, and to just the flight
/// dump when even that fails (e.g. a wounded, uncleanly closed store).
fn collect_stats(store: &str, probe_ops: usize) -> Result<crate::telemetry::export::StatsBundle> {
    use crate::alloc::ReaderManager;
    use crate::coordinator::metrics::{
        record_alloc_stats, record_attach_stats, record_bg_sync_stats, record_health_stats,
        record_oplog_stats, record_sync_stats,
    };
    use crate::telemetry::export::StatsBundle;
    use crate::telemetry::histogram::HistogramSnapshot;
    use crate::telemetry::Op;

    let metrics = Metrics::new();
    let mut lat: Vec<(Op, HistogramSnapshot)> = Vec::new();
    let rw_opts = ManagerOptions { telemetry_sample: 1, ..Default::default() };
    match MetallManager::open_with(store, rw_opts, false, false) {
        Ok(mgr) => {
            if probe_ops > 0 {
                let mut offs = Vec::with_capacity(probe_ops + 1);
                for _ in 0..probe_ops {
                    offs.push(mgr.allocate(64)?);
                }
                // one multi-chunk allocation exercises the large class
                offs.push(mgr.allocate(mgr.chunk_size() * 2)?);
                for off in offs {
                    mgr.deallocate(off)?;
                }
                mgr.sync()?; // epoch cut/serialize/commit/manifest samples
            }
            record_alloc_stats(&metrics, &mgr.stats(), &mgr.shard_stats());
            record_sync_stats(&metrics, &mgr.sync_stats());
            record_bg_sync_stats(&metrics, &mgr.bg_sync_stats());
            record_oplog_stats(&metrics, &mgr.oplog_stats());
            record_health_stats(&metrics, &mgr.health_stats());
            lat = mgr.latency_snapshot();
            mgr.close()?;
            if probe_ops > 0 {
                // a real attach gives the attach/refresh histograms data
                let r = ReaderManager::attach(store)?;
                record_attach_stats(&metrics, &r.attach_stats());
                let rl = r.latency_snapshot();
                r.detach()?;
                for ((_, snap), (_, rs)) in lat.iter_mut().zip(rl.iter()) {
                    snap.merge(rs);
                }
            }
        }
        Err(_) => {
            if let Ok(mgr) = MetallManager::open_read_only(store) {
                record_alloc_stats(&metrics, &mgr.stats(), &mgr.shard_stats());
                record_oplog_stats(&metrics, &mgr.oplog_stats());
                record_health_stats(&metrics, &mgr.health_stats());
                lat = mgr.latency_snapshot();
            } else {
                // wounded / unclean: export empty histograms (all ops
                // still present) plus whatever the flight dump holds
                lat = crate::telemetry::Telemetry::new(0, 1).snapshot();
            }
        }
    }

    let (counters, timers) = metrics.snapshot();
    let mut b = StatsBundle::with_latencies(&lat);
    b.counters = counters.into_iter().collect();
    b.timers = timers.into_iter().collect();
    if let Some(path) = crate::telemetry::recorder::newest_dump(std::path::Path::new(store)) {
        if let Ok(dump) = crate::telemetry::recorder::load(&path) {
            b.events = crate::telemetry::recorder::render_tail(&dump, 16);
        }
    }
    Ok(b)
}

/// `metall attach`: the multi-process snapshot-isolation benchmark. The
/// owner (this process) seeds a GBTL matrix plus a banked adjacency
/// list, commits the first epoch, then keeps ingesting + flushing while
/// `readers` forked reader processes each attach to a pinned epoch, run
/// BFS against it, and `refresh()` forward as new epochs commit. Emits a
/// stub-first trajectory doc to `out` (so CI uploads a meaningful
/// artifact even on a crash mid-bench).
fn run_attach_bench(
    store: &str,
    readers: usize,
    rounds: usize,
    scale: u32,
    out: &str,
) -> Result<i32> {
    use crate::alloc::AttachStats;
    use crate::coordinator::metrics::record_attach_stats;
    use crate::gbtl::GrbMatrix;
    use crate::util::jsonw::JsonObj;
    use std::process::{Command, Stdio};

    let stub = JsonObj::new()
        .str("bench", "attach")
        .str("status", "started")
        .int("readers", readers as i64)
        .int("rounds", rounds as i64)
        .int("scale", scale as i64)
        .raw("results", "[]")
        .finish();
    std::fs::write(out, stub + "\n").with_context(|| format!("write {out}"))?;

    let banks = 64usize;
    let n = 1usize << scale;
    let mgr = if std::path::Path::new(store).join("meta.bin").exists() {
        MetallManager::open(store).context("open datastore")?
    } else {
        MetallManager::create(store).context("create datastore")?
    };
    // seed: a static matrix for the readers' BFS, an adjacency list for
    // the concurrent ingester
    if mgr.find::<GrbMatrix>("mat")?.is_none() {
        let edges = RmatGenerator::graph500(scale, 8).seed(0xA77AC4).generate();
        let mat = GrbMatrix::from_edges(&mgr, n, &edges)?;
        mgr.construct::<GrbMatrix>("mat", mat)?;
    }
    let graph = match mgr.find::<u64>("graph")? {
        Some(off) => BankedAdjacency::open(&mgr, mgr.read(off)),
        None => {
            let g = BankedAdjacency::create(&mgr, banks)?;
            mgr.construct::<u64>("graph", g.offset())?;
            g
        }
    };
    // a rerun against an existing store must not leave a stale end-of-run
    // marker for the readers to trip over
    mgr.destroy("done")?;
    mgr.sync()?; // the first committed epoch a reader can pin

    // Spawn the readers; each touches a ready-marker file right after its
    // attach, and the owner only starts mutating once every marker exists
    // — so "staleness at attach < 1 epoch" is deterministic, not a race.
    let exe = std::env::current_exe().context("current_exe")?;
    let pid = std::process::id();
    let ready_dir = std::env::temp_dir().join(format!("metall-attach-ready-{pid}"));
    std::fs::create_dir_all(&ready_dir)?;
    let mut children = Vec::new();
    for i in 0..readers {
        let ready = ready_dir.join(format!("r{i}"));
        let child = Command::new(&exe)
            .args(["attach-reader", "--store", store, "--ready", ready.to_str().unwrap()])
            .stdout(Stdio::piped())
            .spawn()
            .context("spawn attach reader")?;
        children.push((child, ready));
    }
    let t0 = std::time::Instant::now();
    while children.iter().any(|(_, r)| !r.exists()) {
        if t0.elapsed().as_secs() > 30 {
            bail!("attach readers failed to attach within 30s");
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }

    // Ingest + flush rounds: every round dirties management state (a new
    // named object) so each sync() commits a fresh manifest epoch for the
    // readers to refresh onto.
    let metrics = Metrics::new();
    let cfg = PipelineConfig { workers: 2, batch_size: 2048, queue_depth: 8, nbanks: banks };
    for round in 0..rounds {
        let gen = RmatGenerator::graph500(scale, 2).seed(1000 + round as u64);
        ingest(&mgr, &graph, gen.generate().into_iter(), &cfg, false, &metrics)?;
        // per-run name: reruns on the same store must not collide
        mgr.destroy(&format!("round-{pid}-{round}"))?;
        mgr.construct::<u64>(&format!("round-{pid}-{round}"), round as u64)?;
        mgr.sync()?;
    }
    // the readers poll for this name to know the run is over
    mgr.construct::<u64>("done", rounds as u64)?;
    mgr.sync()?;

    let mut results: Vec<AttachStats> = Vec::new();
    let mut all_ok = true;
    for (child, _) in children {
        let out_c = child.wait_with_output().context("wait for attach reader")?;
        all_ok &= out_c.status.success();
        let text = String::from_utf8_lossy(&out_c.stdout);
        match text.lines().find(|l| l.starts_with("ATTACH_RESULT ")) {
            Some(line) => results.push(parse_attach_result(line)),
            None => all_ok = false,
        }
    }
    let _ = std::fs::remove_dir_all(&ready_dir);
    // owner-side tail latencies (epoch phases, allocs) as alloc.lat.*
    // gauges next to the attach counters
    crate::coordinator::metrics::record_latency_stats(&metrics, &mgr.latency_snapshot());
    let owner_lat: Vec<String> = mgr
        .latency_snapshot()
        .iter()
        .filter(|(_, s)| s.count > 0)
        .map(|(op, s)| {
            let l = crate::telemetry::export::OpLatency::from_snapshot(*op, s);
            JsonObj::new()
                .str("op", l.op)
                .int("count", l.count as i64)
                .int("p50_ns", l.p50 as i64)
                .int("p99_ns", l.p99 as i64)
                .int("p999_ns", l.p999 as i64)
                .finish()
        })
        .collect();
    mgr.close()?;

    // histogram of epochs-behind at attach time: [0, 1, 2, ≥3]
    let mut staleness_hist = [0u64; 4];
    let mut rows = Vec::new();
    for s in &results {
        record_attach_stats(&metrics, s);
        staleness_hist[(s.staleness_epochs as usize).min(3)] += 1;
        rows.push(
            JsonObj::new()
                .int("attach_micros", s.attach_micros as i64)
                .int("staleness_at_attach", s.staleness_epochs as i64)
                .int("refreshes", s.refreshes as i64)
                .int("chunks_overlaid", s.chunks_overlaid as i64)
                .int("side_copies_created", s.side_copies_created as i64)
                .int("side_copies_reused", s.side_copies_reused as i64)
                .finish(),
        );
    }
    let max_staleness = results.iter().map(|s| s.staleness_epochs).max().unwrap_or(u64::MAX);
    let pass = all_ok && results.len() == readers && max_staleness < 1;
    let doc = JsonObj::new()
        .str("bench", "attach")
        .str("status", if pass { "ok" } else { "failed" })
        .int("readers", readers as i64)
        .int("rounds", rounds as i64)
        .int("scale", scale as i64)
        .bool("attach_staleness_lt1", max_staleness < 1)
        .raw(
            "staleness_at_attach_histogram",
            &format!(
                "[{},{},{},{}]",
                staleness_hist[0], staleness_hist[1], staleness_hist[2], staleness_hist[3]
            ),
        )
        .raw("results", &format!("[{}]", rows.join(",")))
        .raw("owner_latency_ns", &format!("[{}]", owner_lat.join(",")))
        .finish();
    std::fs::write(out, doc + "\n").with_context(|| format!("write {out}"))?;

    let (counters, _) = metrics.snapshot();
    for (k, v) in counters
        .iter()
        .filter(|(k, _)| k.starts_with("alloc.attach.") || k.starts_with("alloc.lat."))
    {
        println!("  {k:<36} {v}");
    }
    println!(
        "attach bench: {readers} readers × {rounds} epochs → {out} ({})",
        if pass { "ok" } else { "FAILED" }
    );
    Ok(if pass { 0 } else { 1 })
}

/// One reader process of the attach bench: pin an epoch, report
/// readiness, run BFS over the pinned matrix, then follow the owner's
/// epochs via `refresh()` until the `done` marker object appears. The
/// one-line `ATTACH_RESULT k=v …` report on stdout is the IPC back to
/// the owner.
fn run_attach_reader(store: &str, ready: Option<&str>) -> Result<i32> {
    use crate::alloc::ReaderManager;
    use crate::gbtl::algorithms::bfs_level;
    use crate::gbtl::GrbMatrix;

    let mut r = ReaderManager::attach(store).context("attach")?;
    let staleness_at_attach = r.attach_stats().staleness_epochs;
    if let Some(p) = ready {
        std::fs::write(p, b"attached").context("write ready marker")?;
    }

    let mut bfs_runs = 0u64;
    let mut reached_last = 0usize;
    let mut edges_last = 0u64;
    let mut run_queries = |r: &ReaderManager| -> Result<bool> {
        let off = r
            .find::<GrbMatrix>("mat")?
            .ok_or_else(|| anyhow!("no 'mat' in the pinned epoch"))?;
        let mat: GrbMatrix = r.read(off);
        let levels = bfs_level(r, &mat, 0);
        reached_last = levels.iter().filter(|&&l| l >= 0).count();
        bfs_runs += 1;
        if let Some(goff) = r.find::<u64>("graph")? {
            let g = BankedAdjacency::open(r, r.read(goff));
            let e = g.num_edges(r);
            // epochs only move forward; so must the committed adjacency
            if e < edges_last {
                bail!("adjacency shrank across refresh: {e} < {edges_last}");
            }
            edges_last = e;
        }
        Ok(r.find::<u64>("done")?.is_some())
    };
    let mut done = run_queries(&r)?;
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(120);
    while !done && std::time::Instant::now() < deadline {
        if r.refresh().context("refresh")? {
            done = run_queries(&r)?;
        } else {
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
    }
    let s = r.attach_stats();
    println!(
        "ATTACH_RESULT attach_micros={} staleness_at_attach={staleness_at_attach} \
         refreshes={} chunks_overlaid={} side_copies_created={} side_copies_reused={} \
         bfs_runs={bfs_runs} reached={reached_last} edges={edges_last}",
        s.attach_micros, s.refreshes, s.chunks_overlaid, s.side_copies_created,
        s.side_copies_reused
    );
    r.detach()?;
    Ok(if done { 0 } else { 1 })
}

/// Parse a reader's `ATTACH_RESULT k=v …` line back into stats. Unknown
/// keys are ignored so the reader can report extras for humans.
fn parse_attach_result(line: &str) -> crate::alloc::AttachStats {
    let mut s = crate::alloc::AttachStats::default();
    for kv in line.split_whitespace().skip(1) {
        let Some((k, v)) = kv.split_once('=') else { continue };
        let Ok(v) = v.parse::<u64>() else { continue };
        match k {
            "attach_micros" => s.attach_micros = v,
            // the histogram wants staleness *at attach*, before any
            // refresh caught the reader up
            "staleness_at_attach" => s.staleness_epochs = v,
            "refreshes" => s.refreshes = v,
            "chunks_overlaid" => s.chunks_overlaid = v,
            "side_copies_created" => s.side_copies_created = v,
            "side_copies_reused" => s.side_copies_reused = v,
            _ => {}
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    fn run_cmd(parts: &[&str]) -> i32 {
        run(&parts.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn help_and_version() {
        assert_eq!(run_cmd(&["help"]), 0);
        assert_eq!(run_cmd(&["version"]), 0);
        assert_eq!(run_cmd(&["frobnicate"]), 2);
    }

    #[test]
    fn create_ingest_inspect_snapshot() {
        let d = TempDir::new("cli");
        let store = d.join("s");
        let snap = d.join("snap");
        let store_s = store.to_str().unwrap();
        assert_eq!(
            run_cmd(&["ingest", "--store", store_s, "--scale", "8", "--threads", "2",
                      "--edge-factor", "4", "--banks", "32"]),
            0
        );
        assert_eq!(run_cmd(&["inspect", "--store", store_s]), 0);
        assert_eq!(
            run_cmd(&["snapshot", "--store", store_s, "--to", snap.to_str().unwrap()]),
            0
        );
        // the snapshot is a valid, openable datastore
        assert_eq!(run_cmd(&["inspect", "--store", snap.to_str().unwrap()]), 0);
        // the sync subcommand commits an epoch and surfaces the metrics
        assert_eq!(run_cmd(&["sync", "--store", store_s]), 0);
        assert_eq!(run_cmd(&["sync", "--store", store_s, "--watermark-mb", "4"]), 0);
    }

    #[test]
    fn missing_args_error() {
        assert!(run(&["create".to_string()]).is_err());
    }

    #[test]
    fn stats_exports_valid_prometheus_and_json() {
        let d = TempDir::new("cli-stats");
        let store = d.join("s");
        let store_s = store.to_str().unwrap();
        assert_eq!(run_cmd(&["create", "--store", store_s]), 0);

        // the bundle behind both formats: probed, so every instrumented
        // path has real samples
        let b = collect_stats(store_s, 64).unwrap();
        let text = crate::telemetry::export::render_prometheus(&b);
        crate::telemetry::export::validate_prometheus(&text).unwrap();
        for op in ["alloc_small", "alloc_large", "epoch_commit", "attach"] {
            let name = format!("metall_alloc_lat_{op}_ns");
            assert!(text.contains(&format!("{name}{{quantile=\"0.99\"}}")), "{name} p99 missing");
            assert!(text.contains(&format!("{name}{{quantile=\"0.999\"}}")), "{name} p999 missing");
        }
        // the probe really recorded: alloc_small count > 0
        let count_line = text
            .lines()
            .find(|l| l.starts_with("metall_alloc_lat_alloc_small_ns_count"))
            .unwrap();
        let n: u64 = count_line.split_whitespace().nth(1).unwrap().parse().unwrap();
        assert!(n >= 64, "probe recorded {n} alloc_small samples");
        let j = crate::telemetry::export::render_json(&b);
        assert!(j.contains("\"epoch_commit\"") && j.contains("\"p999_ns\""));

        // the subcommands run end-to-end
        assert_eq!(run_cmd(&["stats", "--store", store_s, "--format", "prom"]), 0);
        assert_eq!(
            run_cmd(&["stats", "--store", store_s, "--format", "json", "--probe-ops", "0"]),
            0
        );
        assert!(run(&[
            "stats".to_string(),
            "--store".to_string(),
            store_s.to_string(),
            "--format".to_string(),
            "xml".to_string(),
        ])
        .is_err());
    }

    #[test]
    fn trace_renders_flight_dump() {
        let d = TempDir::new("cli-trace");
        let store = d.join("s");
        let store_s = store.to_str().unwrap();
        // no store yet → no dump
        std::fs::create_dir_all(&store).unwrap();
        assert_eq!(run_cmd(&["trace", "--store", store_s]), 1);
        // any owner session leaves a flight ring with at least the Open
        // and epoch-lifecycle events
        assert_eq!(run_cmd(&["create", "--store", store_s]), 0);
        assert_eq!(run_cmd(&["sync", "--store", store_s]), 0);
        assert_eq!(run_cmd(&["trace", "--store", store_s]), 0);
        assert_eq!(run_cmd(&["trace", "--store", store_s, "--tail", "4"]), 0);
        // doctor surfaces the tail alongside its report
        assert_eq!(run_cmd(&["doctor", "--store", store_s]), 0);
    }
}

//! Hand-rolled CLI (the offline image carries no clap). The launcher for
//! the whole system: datastore lifecycle, streaming ingestion, and
//! PJRT-backed analytics.

use anyhow::{anyhow, bail, Context, Result};

use crate::alloc::{ManagerOptions, MetallManager};
use crate::containers::BankedAdjacency;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pipeline::{ingest, PipelineConfig};
use crate::graph::ell_cache::{self, EllCache};
use crate::graph::rmat::RmatGenerator;
use crate::runtime::engine::AnalyticsEngine;
use crate::util::human;

const HELP: &str = "\
metall — persistent-memory data analytics (Metall reproduction)

USAGE:
    metall <SUBCOMMAND> [OPTIONS]

SUBCOMMANDS:
    create    --store <dir>                          create an empty datastore
    ingest    --store <dir> --scale <s> [--threads n] [--edge-factor 16]
              [--banks 1024] [--batch 4096] [--seed 0] [--append]
                                                     R-MAT stream → banked adjacency list
    inspect   --store <dir>                          named objects + usage stats
    snapshot  --store <dir> --to <dir>               reflink/copy snapshot
    sync      --store <dir> [--watermark-mb n] [--interval-ms n]
                                                     run a background sync epoch and print
                                                     the alloc.sync.* / alloc.bgsync.* metrics
    analyze   --store <dir> --algo <pagerank|bfs> [--artifacts artifacts]
              [--iters 50] [--source 0] [--top 5]    run analytics via the PJRT engine
                                                     (uses/refreshes the persistent ELL cache)
    doctor    --store <dir>                          validate datastore integrity
    version | help
";

fn req<'a>(args: &'a crate::bench_util::BenchArgs, key: &str) -> Result<&'a str> {
    args.get(key).ok_or_else(|| anyhow!("missing required --{key}\n\n{HELP}"))
}

/// Run the CLI; returns the process exit code.
pub fn run(argv: &[String]) -> Result<i32> {
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    // BenchArgs::parse reads process args; re-parse from argv[1..] instead
    let args = parse_args(&argv[1..]);
    match cmd {
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(0)
        }
        "version" | "--version" => {
            println!("metall-rs {}", env!("CARGO_PKG_VERSION"));
            Ok(0)
        }
        "create" => {
            let store = req(&args, "store")?;
            let mgr = MetallManager::create(store).context("create datastore")?;
            mgr.close()?;
            println!("created datastore at {store}");
            Ok(0)
        }
        "ingest" => {
            let store = req(&args, "store")?;
            let scale = args.get_usize("scale", 16) as u32;
            let threads = args.get_usize("threads", 4);
            let ef = args.get_usize("edge-factor", 16);
            let banks = args.get_usize("banks", 1024);
            let batch = args.get_usize("batch", 4096);
            let seed = args.get_usize("seed", 0) as u64;
            let append = args.has("append");

            let mgr = if append {
                MetallManager::open(store).context("open datastore")?
            } else {
                MetallManager::create(store).context("create datastore")?
            };
            let graph = match mgr.find::<u64>("graph")? {
                Some(off) => BankedAdjacency::open(&mgr, mgr.read(off)),
                None => {
                    let g = BankedAdjacency::create(&mgr, banks)?;
                    mgr.construct::<u64>("graph", g.offset())?;
                    g
                }
            };
            let gen = RmatGenerator::graph500(scale, ef).seed(seed);
            let metrics = Metrics::new();
            let cfg = PipelineConfig {
                workers: threads,
                batch_size: batch,
                queue_depth: 16,
                nbanks: banks,
            };
            println!(
                "ingesting R-MAT SCALE {scale} (|V|=2^{scale}, {} undirected edges) with {threads} workers…",
                gen.num_edges()
            );
            let rep = ingest(&mgr, &graph, gen.generate().into_iter(), &cfg, true, &metrics)?;
            println!(
                "ingested {} edges in {} ({})",
                rep.edges,
                human::duration(rep.ingest_secs),
                human::rate(rep.edges_per_sec)
            );
            mgr.close()?;
            Ok(0)
        }
        "inspect" => {
            let store = req(&args, "store")?;
            let mgr = MetallManager::open_read_only(store).context("open datastore")?;
            println!("datastore: {store}");
            println!("chunk size: {}", human::bytes(mgr.chunk_size() as u64));
            println!("segment used: {}", human::bytes(mgr.used_segment_bytes() as u64));
            println!(
                "file blocks allocated: {}",
                human::bytes(mgr.segment().allocated_file_blocks()? * 512)
            );
            println!("named objects ({}):", mgr.num_named());
            for (name, off, size) in mgr.named_list() {
                println!("  {name:<24} offset={off:<12} size={size}");
            }
            if let Some(off) = mgr.find::<u64>("graph")? {
                let g = BankedAdjacency::open(&mgr, mgr.read(off));
                println!(
                    "graph: {} vertices, {} directed edges, {} banks",
                    g.num_vertices(&mgr),
                    g.num_edges(&mgr),
                    g.nbanks()
                );
            }
            Ok(0)
        }
        "snapshot" => {
            let store = req(&args, "store")?;
            let to = req(&args, "to")?;
            let mgr = MetallManager::open(store).context("open datastore")?;
            let method = mgr.snapshot(to)?;
            mgr.close()?;
            println!("snapshot {store} -> {to} ({method:?})");
            Ok(0)
        }
        "sync" => {
            let store = req(&args, "store")?;
            let o = ManagerOptions {
                sync_watermark_bytes: args.get_usize("watermark-mb", 0) << 20,
                sync_interval_ms: args.get_usize("interval-ms", 0) as u64,
                ..Default::default()
            };
            let mgr = MetallManager::open_with(store, o, false, false).context("open datastore")?;
            let ticket = mgr.sync_async()?;
            let epoch = ticket.generation();
            ticket.wait()?;
            println!("{store}: background flush epoch {epoch} durably committed");
            let metrics = Metrics::new();
            crate::coordinator::metrics::record_sync_stats(&metrics, &mgr.sync_stats());
            crate::coordinator::metrics::record_bg_sync_stats(&metrics, &mgr.bg_sync_stats());
            let (counters, _) = metrics.snapshot();
            for (k, v) in counters {
                println!("  {k:<36} {v}");
            }
            mgr.close()?;
            Ok(0)
        }
        "analyze" => {
            let store = req(&args, "store")?;
            let algo = req(&args, "algo")?.to_string();
            let artifacts = args.get("artifacts").unwrap_or("artifacts").to_string();
            let iters = args.get_usize("iters", 50);
            let source = args.get_usize("source", 0);
            let top = args.get_usize("top", 5);

            // Prefer the persistent ELL cache (built by a previous
            // analyze/ingest); rebuild and persist when stale/missing.
            let (ell, n) = {
                let ro = MetallManager::open_read_only(store).context("open datastore")?;
                let off = ro
                    .find::<u64>("graph")?
                    .ok_or_else(|| anyhow!("no graph in datastore (run ingest first)"))?;
                let graph = BankedAdjacency::open(&ro, ro.read(off));
                let cached = match ro.find::<EllCache>(ell_cache::CACHE_NAME)? {
                    Some(coff) => ro.read::<EllCache>(coff).load(&ro, &graph),
                    None => None,
                };
                match cached {
                    Some(ell) => {
                        println!("using persistent ELL cache ({} fragments)", ell.f);
                        let n = ell.n;
                        (ell, n)
                    }
                    None => {
                        drop(ro); // reopen writable to refresh the cache
                        let rw = MetallManager::open(store).context("open datastore rw")?;
                        let off = rw.find::<u64>("graph")?.unwrap();
                        let graph = BankedAdjacency::open(&rw, rw.read(off));
                        println!("(re)building ELL cache…");
                        let cache = EllCache::build(&rw, &graph, 32)?;
                        if let Some(old) = rw.find::<EllCache>(ell_cache::CACHE_NAME)? {
                            rw.read::<EllCache>(old).destroy(&rw)?;
                            rw.destroy(ell_cache::CACHE_NAME)?;
                        }
                        rw.construct::<EllCache>(ell_cache::CACHE_NAME, cache)?;
                        let ell = cache.load_unchecked(&rw);
                        rw.close()?;
                        let n = ell.n;
                        (ell, n)
                    }
                }
            };
            let mgr = MetallManager::open_read_only(store)?;
            let engine = AnalyticsEngine::new(&artifacts).context("load artifacts")?;
            match algo.as_str() {
                "pagerank" => {
                    let run = engine.pagerank(&ell, iters, 1e-7).context("pagerank")?;
                    println!(
                        "pagerank: {} iters, exec {} (compile {})",
                        run.iterations,
                        human::duration(run.exec_secs),
                        human::duration(run.compile_secs)
                    );
                    let mut idx: Vec<usize> = (0..n).collect();
                    idx.sort_by(|&a, &b| run.values[b].partial_cmp(&run.values[a]).unwrap());
                    for &v in idx.iter().take(top) {
                        println!("  vertex {v:<10} rank {:.6}", run.values[v]);
                    }
                }
                "bfs" => {
                    let run = engine.bfs(&ell, source).context("bfs")?;
                    let reached = run.values.iter().filter(|&&l| l >= 0.0).count();
                    let max_l = run.values.iter().cloned().fold(0f32, f32::max);
                    println!(
                        "bfs from {source}: {} levels, {reached}/{n} reached, exec {}",
                        max_l as i64,
                        human::duration(run.exec_secs)
                    );
                }
                other => bail!("unknown --algo {other} (pagerank|bfs)"),
            }
            Ok(0)
        }
        "doctor" => {
            let store = req(&args, "store")?;
            let mgr = MetallManager::open_read_only(store).context("open datastore")?;
            let report = mgr.doctor()?;
            if report.is_empty() {
                println!("{store}: OK — management data consistent, all named \
                          objects within the mapped segment");
                Ok(0)
            } else {
                for finding in &report {
                    println!("WARN: {finding}");
                }
                Ok(1)
            }
        }
        other => {
            eprintln!("unknown subcommand: {other}\n");
            print!("{HELP}");
            Ok(2)
        }
    }
}

/// Parse `--key value` pairs from an argv slice.
fn parse_args(argv: &[String]) -> crate::bench_util::BenchArgs {
    crate::bench_util::BenchArgs::from_slice(argv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    fn run_cmd(parts: &[&str]) -> i32 {
        run(&parts.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn help_and_version() {
        assert_eq!(run_cmd(&["help"]), 0);
        assert_eq!(run_cmd(&["version"]), 0);
        assert_eq!(run_cmd(&["frobnicate"]), 2);
    }

    #[test]
    fn create_ingest_inspect_snapshot() {
        let d = TempDir::new("cli");
        let store = d.join("s");
        let snap = d.join("snap");
        let store_s = store.to_str().unwrap();
        assert_eq!(
            run_cmd(&["ingest", "--store", store_s, "--scale", "8", "--threads", "2",
                      "--edge-factor", "4", "--banks", "32"]),
            0
        );
        assert_eq!(run_cmd(&["inspect", "--store", store_s]), 0);
        assert_eq!(
            run_cmd(&["snapshot", "--store", store_s, "--to", snap.to_str().unwrap()]),
            0
        );
        // the snapshot is a valid, openable datastore
        assert_eq!(run_cmd(&["inspect", "--store", snap.to_str().unwrap()]), 0);
        // the sync subcommand commits an epoch and surfaces the metrics
        assert_eq!(run_cmd(&["sync", "--store", store_s]), 0);
        assert_eq!(run_cmd(&["sync", "--store", store_s, "--watermark-mb", "4"]), 0);
    }

    #[test]
    fn missing_args_error() {
        assert!(run(&["create".to_string()]).is_err());
    }
}

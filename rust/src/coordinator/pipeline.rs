//! Streaming graph-ingestion pipeline — the L3 orchestrator.
//!
//! Topology: a producer thread batches the incoming edge stream and
//! feeds a **bounded** channel (backpressure: the producer blocks when
//! the workers fall behind); worker threads drain batches, shard them by
//! bank, and insert into the persistent [`BankedAdjacency`] under the
//! per-bank mutexes (paper §6.1). Periodic flushes snapshot progress
//! (paper §6.4.1's incremental iterations).

use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::alloc::SegmentAlloc;
use crate::baselines::BenchAllocator;
use crate::containers::BankedAdjacency;
use crate::coordinator::metrics::Metrics;
use crate::error::{Error, Result};

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Worker (inserter) threads.
    pub workers: usize,
    /// Edges per batch handed to a worker.
    pub batch_size: usize,
    /// Bounded-queue depth in batches (backpressure window).
    pub queue_depth: usize,
    /// Banks in the adjacency list (paper: m = 1024).
    pub nbanks: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self { workers: 4, batch_size: 4096, queue_depth: 16, nbanks: 1024 }
    }
}

/// Outcome of one ingestion run.
#[derive(Clone, Debug)]
pub struct IngestReport {
    pub edges: u64,
    pub batches: u64,
    pub ingest_secs: f64,
    pub edges_per_sec: f64,
}

/// Run the pipeline: stream `edges` into `graph` over allocator `alloc`.
///
/// The producer applies the batching; workers contend only on bank
/// mutexes. `undirected` inserts each edge in both directions (the
/// paper's benchmark semantics: "the number of actually inserted edges
/// is (2^s)×16×2").
pub fn ingest<A>(
    alloc: &A,
    graph: &BankedAdjacency,
    edges: impl Iterator<Item = (u64, u64)> + Send,
    cfg: &PipelineConfig,
    undirected: bool,
    metrics: &Metrics,
) -> Result<IngestReport>
where
    A: BenchAllocator + SegmentAlloc,
{
    let t0 = Instant::now();
    let (tx, rx) = sync_channel::<Vec<(u64, u64)>>(cfg.queue_depth);
    let rx: Arc<Mutex<Receiver<Vec<(u64, u64)>>>> = Arc::new(Mutex::new(rx));
    let nworkers = cfg.workers.max(1);
    let batch_size = cfg.batch_size.max(1);

    let (edges_total, batches_total) = std::thread::scope(|s| -> Result<(u64, u64)> {
        // workers
        let mut handles = Vec::new();
        for _ in 0..nworkers {
            let rx = rx.clone();
            handles.push(s.spawn(move || -> Result<(u64, u64)> {
                let mut edges = 0u64;
                let mut batches = 0u64;
                loop {
                    let batch = {
                        // a sibling that panicked while holding the
                        // receiver poisons the mutex; the channel itself
                        // is still sound, so keep draining rather than
                        // cascading the panic through every worker
                        let guard = rx.lock().unwrap_or_else(|p| p.into_inner());
                        guard.recv()
                    };
                    match batch {
                        Ok(b) => {
                            edges += b.len() as u64;
                            batches += 1;
                            graph.insert_batch(alloc, &b)?;
                        }
                        Err(_) => return Ok((edges, batches)), // channel closed
                    }
                }
            }));
        }
        // Give up our receiver reference: once every worker has exited
        // (error or panic), the channel closes and the producer's send
        // fails fast instead of blocking forever on a full queue that
        // nobody will ever drain.
        drop(rx);
        // producer (this thread)
        let mut batch = Vec::with_capacity(batch_size);
        let mut stall_ns = 0u64;
        for (src, dst) in edges {
            batch.push((src, dst));
            if undirected {
                batch.push((dst, src));
            }
            if batch.len() >= batch_size {
                let full = std::mem::replace(&mut batch, Vec::with_capacity(batch_size));
                let t = Instant::now();
                // send fails only when every worker has exited (all
                // receivers dropped) — they errored or panicked. Stop
                // producing and fall through to the join below, which
                // reports what actually went wrong.
                if tx.send(full).is_err() {
                    break;
                }
                stall_ns += t.elapsed().as_nanos() as u64;
            }
        }
        if !batch.is_empty() {
            let _ = tx.send(batch);
        }
        drop(tx); // close channel: workers drain and exit
        metrics.add_time("producer_stall", stall_ns);

        // Join every worker before judging the run: a panic or an
        // insert_batch error in one must not leave siblings detached,
        // and the caller gets the first underlying error (panics are
        // reported only when no worker produced a real error).
        let mut edges_total = 0;
        let mut batches_total = 0;
        let mut first_err: Option<Error> = None;
        let mut panicked = 0usize;
        for h in handles {
            match h.join() {
                Ok(Ok((e, b))) => {
                    edges_total += e;
                    batches_total += b;
                }
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => panicked += 1,
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        if panicked > 0 {
            return Err(Error::Runtime(format!("{panicked} pipeline worker(s) panicked")));
        }
        Ok((edges_total, batches_total))
    })?;

    let ingest_secs = t0.elapsed().as_secs_f64();
    metrics.add("edges_ingested", edges_total);
    metrics.add("batches", batches_total);
    metrics.add_time("ingest", (ingest_secs * 1e9) as u64);
    Ok(IngestReport {
        edges: edges_total,
        batches: batches_total,
        ingest_secs,
        edges_per_sec: edges_total as f64 / ingest_secs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::{ManagerOptions, MetallManager};
    use crate::graph::rmat::RmatGenerator;
    use crate::util::tmp::TempDir;

    #[test]
    fn pipeline_ingests_everything() {
        let d = TempDir::new("pipe1");
        let m = MetallManager::create_with(d.join("s"), ManagerOptions::small_for_tests())
            .unwrap();
        let g = BankedAdjacency::create(&m, 64).unwrap();
        let gen = RmatGenerator::graph500(8, 4).seed(5);
        let edges = gen.generate();
        let metrics = Metrics::new();
        let cfg = PipelineConfig { workers: 4, batch_size: 100, queue_depth: 4, nbanks: 64 };
        let rep = ingest(&m, &g, edges.iter().copied(), &cfg, true, &metrics).unwrap();
        assert_eq!(rep.edges, 2 * edges.len() as u64, "undirected doubling");
        assert_eq!(g.num_edges(&m), rep.edges);
        assert_eq!(metrics.get("edges_ingested"), rep.edges);
        m.close().unwrap();
    }

    #[test]
    fn directed_mode_and_degree_integrity() {
        let d = TempDir::new("pipe2");
        let m = MetallManager::create_with(d.join("s"), ManagerOptions::small_for_tests())
            .unwrap();
        let g = BankedAdjacency::create(&m, 16).unwrap();
        let edges: Vec<(u64, u64)> = (0..1000u64).map(|i| (i % 10, i)).collect();
        let metrics = Metrics::new();
        let cfg = PipelineConfig { workers: 3, batch_size: 64, queue_depth: 2, nbanks: 16 };
        let rep = ingest(&m, &g, edges.iter().copied(), &cfg, false, &metrics).unwrap();
        assert_eq!(rep.edges, 1000);
        for v in 0..10 {
            assert_eq!(g.degree(&m, v), 100, "vertex {v}");
        }
        m.close().unwrap();
    }

    #[test]
    fn worker_failure_surfaces_error_instead_of_panicking() {
        // A segment too small for the stream: insert_batch runs out of
        // space mid-run. The old code panicked twice over — the producer
        // on `send` once the workers were gone, then the join on the
        // workers' Err — instead of reporting the allocation failure.
        let d = TempDir::new("pipe-fail");
        let mut o = ManagerOptions::small_for_tests();
        o.vm_reserve = 16 * o.chunk_size; // a handful of chunks only
        let m = MetallManager::create_with(d.join("s"), o).unwrap();
        let g = BankedAdjacency::create(&m, 4).unwrap();
        // far more edges than the reservation can hold; small batches +
        // shallow queue keep the producer sending after workers die
        let edges = (0..2_000_000u64).map(|i| (i % 1024, i));
        let cfg = PipelineConfig { workers: 3, batch_size: 64, queue_depth: 2, nbanks: 4 };
        let err = ingest(&m, &g, edges, &cfg, false, &Metrics::new())
            .expect_err("segment exhaustion must surface as Err");
        // the first underlying insert error, not a panic or join artifact
        assert!(
            matches!(err, crate::error::Error::Alloc(_)),
            "expected the workers' allocation failure, got: {err}"
        );
        // the manager survives; callers may still sync/close it
        drop(m);
    }

    #[test]
    fn pipeline_result_persists() {
        let d = TempDir::new("pipe3");
        let store = d.join("s");
        let head;
        {
            let m = MetallManager::create_with(&store, ManagerOptions::small_for_tests())
                .unwrap();
            let g = BankedAdjacency::create(&m, 8).unwrap();
            head = g.offset();
            m.construct::<u64>("graph", head).unwrap();
            let edges: Vec<(u64, u64)> = (0..500u64).map(|i| (i % 7, i + 1)).collect();
            ingest(
                &m,
                &g,
                edges.into_iter(),
                &PipelineConfig { workers: 2, batch_size: 50, queue_depth: 2, nbanks: 8 },
                false,
                &Metrics::new(),
            )
            .unwrap();
            m.close().unwrap();
        }
        let m = MetallManager::open(&store).unwrap();
        let g = BankedAdjacency::open(&m, m.read(m.find::<u64>("graph").unwrap().unwrap()));
        assert_eq!(g.num_edges(&m), 500);
        m.close().unwrap();
    }
}

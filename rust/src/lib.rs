//! # metall-rs
//!
//! A Rust reproduction of **Metall: A Persistent Memory Allocator For
//! Data-Centric Analytics** (Iwabuchi, Youssef, Velusamy, Gokhale, Pearce;
//! 2021), embedded in a three-layer rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the Metall persistent allocator itself, its
//!   storage substrates (multi-file mmap segments, `/proc/self/pagemap`
//!   dirty scanning, batch-synchronized mmap, reflink snapshots, simulated
//!   network file systems), position-independent persistent containers, the
//!   baseline allocators the paper evaluates against, a GraphBLAS library
//!   (GBTL analog), and a streaming graph-ingestion coordinator.
//! - **L2/L1 (build-time python, `python/compile/`)** — GraphBLAS analytic
//!   steps (PageRank / BFS over padded ELL adjacency) written in JAX with
//!   Pallas kernels for the per-row semiring reduction, AOT-lowered to HLO
//!   text and executed from rust through the PJRT CPU client
//!   ([`runtime`]). Python is never on the request path.
//!
//! ## Quick start
//!
//! ```no_run
//! use metall_rs::alloc::MetallManager;
//! use metall_rs::containers::PVec;
//!
//! // create a datastore, persist a vector, reattach later
//! let mgr = MetallManager::create("/tmp/mydata").unwrap();
//! let v = PVec::<u64>::create(&mgr).unwrap();
//! v.push(&mgr, 42).unwrap();
//! mgr.construct::<u64>("answers", v.offset()).unwrap();
//! mgr.close().unwrap();
//!
//! let mgr = MetallManager::open("/tmp/mydata").unwrap();
//! let off = mgr.find::<u64>("answers").unwrap().unwrap();
//! let v = PVec::<u64>::from_offset(mgr.read(off));
//! assert_eq!(v.get(&mgr, 0), 42);
//! ```

pub mod error;
pub mod util;
pub mod bench_util;
pub mod numa;
pub mod storage;
pub mod telemetry;
pub mod alloc;
pub mod containers;
pub mod baselines;
pub mod graph;
pub mod gbtl;
pub mod runtime;
pub mod coordinator;
pub mod experiments;

pub use error::{Error, Result};

//! Persistence-policy integration tests: snapshot consistency (§3.3),
//! snapshots (§3.4), crash handling, bs-mmap persistence (§5), and
//! multi-generation reattach chains.

use metall_rs::alloc::{ManagerOptions, MetallManager};
use metall_rs::containers::{BankedAdjacency, PHashMapU64, PVec};
use metall_rs::util::rng::Xoshiro256ss;
use metall_rs::util::tmp::TempDir;

fn opts() -> ManagerOptions {
    ManagerOptions::small_for_tests()
}

/// Five generations of open → mutate → close; all data accumulates.
#[test]
fn multi_generation_reattach_chain() {
    let d = TempDir::new("gen");
    let store = d.join("s");
    {
        let m = MetallManager::create_with(&store, opts()).unwrap();
        let v = PVec::<u64>::create(&m).unwrap();
        m.construct::<u64>("log", v.offset()).unwrap();
        m.close().unwrap();
    }
    for generation in 0..5u64 {
        let m = MetallManager::open(&store).unwrap();
        let v = PVec::<u64>::from_offset(m.read(m.find::<u64>("log").unwrap().unwrap()));
        for i in 0..100 {
            v.push(&m, generation * 1000 + i).unwrap();
        }
        m.close().unwrap();
    }
    let m = MetallManager::open_read_only(&store).unwrap();
    let v = PVec::<u64>::from_offset(m.read(m.find::<u64>("log").unwrap().unwrap()));
    assert_eq!(v.len(&m), 500);
    assert_eq!(v.get(&m, 0), 0);
    assert_eq!(v.get(&m, 499), 4099);
}

/// Crash before close → store refuses plain open; a pre-crash snapshot
/// opens fine and holds the snapshotted state (the paper's §3.3
/// recommended workflow).
#[test]
fn crash_recovery_via_snapshot() {
    let d = TempDir::new("crash");
    let store = d.join("s");
    let snap = d.join("snap");
    {
        let m = MetallManager::create_with(&store, opts()).unwrap();
        let off = m.construct::<u64>("state", 1).unwrap();
        m.snapshot(&snap).unwrap();
        m.write::<u64>(off, 2);
        // crash: no close()
        std::mem::forget(m);
    }
    assert!(MetallManager::open(&store).is_err(), "dirty store refused");
    let s = MetallManager::open(&snap).unwrap();
    let off = s.find::<u64>("state").unwrap().unwrap();
    assert_eq!(s.read::<u64>(off), 1, "snapshot holds pre-crash state");
    s.close().unwrap();
}

/// Snapshots are fully independent: divergent writes after the fork.
#[test]
fn snapshot_divergence() {
    let d = TempDir::new("fork");
    let store = d.join("a");
    let snap = d.join("b");
    let m = MetallManager::create_with(&store, opts()).unwrap();
    let v = PVec::<u64>::create(&m).unwrap();
    m.construct::<u64>("v", v.offset()).unwrap();
    for i in 0..10 {
        v.push(&m, i).unwrap();
    }
    m.snapshot(&snap).unwrap();
    for i in 10..20 {
        v.push(&m, i).unwrap();
    }
    m.close().unwrap();

    let a = MetallManager::open(&store).unwrap();
    let b = MetallManager::open(&snap).unwrap();
    let va = PVec::<u64>::from_offset(a.read(a.find::<u64>("v").unwrap().unwrap()));
    let vb = PVec::<u64>::from_offset(b.read(b.find::<u64>("v").unwrap().unwrap()));
    assert_eq!(va.len(&a), 20);
    assert_eq!(vb.len(&b), 10);
    // mutate the snapshot; original untouched
    for i in 0..5 {
        vb.push(&b, 900 + i).unwrap();
    }
    assert_eq!(va.len(&a), 20);
    a.close().unwrap();
    b.close().unwrap();
}

/// bs-mmap mode (§5): private mapping, explicit user msync, data
/// reattachable afterwards; kernel never wrote behind our back.
#[test]
fn bsmmap_mode_full_graph_roundtrip() {
    let d = TempDir::new("bsgraph");
    let store = d.join("s");
    let mut o = opts();
    o.private_mode = true;
    let nedges = 5_000u64;
    {
        let m = MetallManager::create_with(&store, o).unwrap();
        let g = BankedAdjacency::create(&m, 32).unwrap();
        m.construct::<u64>("g", g.offset()).unwrap();
        let mut rng = Xoshiro256ss::new(6);
        for _ in 0..nedges {
            g.insert_edge(&m, rng.gen_range(500), rng.gen_range(500)).unwrap();
        }
        let st = m.bs_msync().unwrap();
        assert!(st.dirty_pages > 0);
        assert!(st.runs <= st.dirty_pages, "coalescing never increases run count");
        m.close().unwrap();
    }
    let m = MetallManager::open(&store).unwrap();
    let g = BankedAdjacency::open(&m, m.read(m.find::<u64>("g").unwrap().unwrap()));
    assert_eq!(g.num_edges(&m), nedges);
    m.close().unwrap();
}

/// Mixed container graph (map of vecs + strings) across reattach — the
/// "custom complex persistent data structure" claim (§7.4).
#[test]
fn composite_structure_roundtrip() {
    use metall_rs::containers::PString;
    let d = TempDir::new("composite");
    let store = d.join("s");
    {
        let m = MetallManager::create_with(&store, opts()).unwrap();
        let map = PHashMapU64::<PVec<u64>>::create(&m).unwrap();
        m.construct::<u64>("map", map.offset()).unwrap();
        for k in 0..50u64 {
            let v = map.get_or_insert_with(&m, k, |a| PVec::<u64>::create(a)).unwrap();
            for i in 0..k {
                v.push(&m, i * k).unwrap();
            }
        }
        let label = PString::create(&m, "composite-test-v1").unwrap();
        m.construct::<u64>("label", label.offset()).unwrap();
        m.close().unwrap();
    }
    let m = MetallManager::open(&store).unwrap();
    let map = PHashMapU64::<PVec<u64>>::from_offset(
        m.read(m.find::<u64>("map").unwrap().unwrap()),
    );
    assert_eq!(map.len(&m), 50);
    let v49 = map.get(&m, 49).unwrap();
    assert_eq!(v49.len(&m), 49);
    assert_eq!(v49.get(&m, 48), 48 * 49);
    let label = metall_rs::containers::PString::from_offset(
        m.read(m.find::<u64>("label").unwrap().unwrap()),
    );
    assert_eq!(label.to_string(&m), "composite-test-v1");
    m.close().unwrap();
}

/// destroy() frees space that a subsequent construct can reuse, and the
/// name directory stays consistent across reattach.
#[test]
fn destroy_and_name_directory_persistence() {
    let d = TempDir::new("destroy");
    let store = d.join("s");
    {
        let m = MetallManager::create_with(&store, opts()).unwrap();
        m.construct::<u64>("a", 1).unwrap();
        m.construct::<u64>("b", 2).unwrap();
        m.construct::<u64>("c", 3).unwrap();
        assert!(m.destroy("b").unwrap());
        m.close().unwrap();
    }
    let m = MetallManager::open(&store).unwrap();
    assert_eq!(m.num_named(), 2);
    assert!(m.find::<u64>("b").unwrap().is_none());
    assert_eq!(m.read::<u64>(m.find::<u64>("c").unwrap().unwrap()), 3);
    // name can be reused after destroy
    m.construct::<u64>("b", 22).unwrap();
    assert_eq!(m.read::<u64>(m.find::<u64>("b").unwrap().unwrap()), 22);
    m.close().unwrap();
}

/// Corrupted management data is detected on open: with a single manifest
/// (one close, no fallback epoch) a bit-flip in either a section file or
/// the manifest itself must refuse the store — the checksums catch it.
#[test]
fn corrupt_management_detected() {
    use metall_rs::alloc::mgmt_io;

    let flip_mid = |p: &std::path::Path| {
        let mut bytes = std::fs::read(p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(p, &bytes).unwrap();
    };
    for target in ["section", "manifest"] {
        let d = TempDir::new(&format!("corrupt-{target}"));
        let store = d.join("s");
        {
            let m = MetallManager::create_with(&store, opts()).unwrap();
            m.construct::<u64>("x", 5).unwrap();
            m.close().unwrap();
        }
        let epochs = mgmt_io::list_manifest_epochs(&store).unwrap();
        assert_eq!(epochs.len(), 1, "one close → one manifest, no fallback");
        let man = mgmt_io::read_manifest(&store, epochs[0]).unwrap();
        match target {
            "section" => {
                // flip a byte in the chunk-directory section
                let rec = man.section(mgmt_io::SectionId::Chunks).unwrap();
                flip_mid(&store.join(&rec.file));
            }
            _ => flip_mid(&store.join(mgmt_io::manifest_file_name(epochs[0]))),
        }
        assert!(
            MetallManager::open(&store).is_err(),
            "bit-flipped {target} must not open cleanly"
        );
    }
}

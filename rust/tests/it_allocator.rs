//! Property-style integration tests of the allocator layer: randomized
//! allocation traces checked against a model, across Metall and every
//! baseline (they all speak `SegmentAlloc`).

use std::collections::HashMap;

use metall_rs::alloc::size_class::{bin_of, size_of_bin};
use metall_rs::alloc::{pin_thread_vcpu, ManagerOptions, MetallManager, SegmentAlloc};
use metall_rs::numa::Topology;
use metall_rs::storage::mmap::page_size;
use metall_rs::baselines::bip::BipAllocator;
use metall_rs::baselines::pmemkind::{MadvMode, PmemKindAllocator};
use metall_rs::baselines::ralloc_like::RallocLike;
use metall_rs::storage::segment::SegmentOptions;
use metall_rs::util::rng::Xoshiro256ss;
use metall_rs::util::tmp::TempDir;

const CHUNK: usize = 64 << 10;

fn seg_opts() -> SegmentOptions {
    SegmentOptions::default().with_file_size(1 << 20).with_vm_reserve(4 << 30)
}

/// Random alloc/write/verify/free trace against a shadow model. Checks:
/// values never corrupted (=> live allocations never overlap or move),
/// deallocate accepts exactly the live set.
fn fuzz_against_model<A: SegmentAlloc>(a: &A, seed: u64, steps: usize, max_size: usize) {
    let mut rng = Xoshiro256ss::new(seed);
    let mut live: Vec<(u64, u64, usize)> = Vec::new(); // (offset, tag, size)
    for step in 0..steps {
        let do_alloc = live.is_empty() || rng.next_f64() < 0.6;
        if do_alloc {
            let size = 8 + rng.gen_range(max_size as u64 - 8) as usize;
            let off = a.allocate(size).unwrap();
            let tag = rng.next_u64();
            // stamp head and tail of the allocation
            a.write_pod::<u64>(off, tag);
            if size >= 16 {
                a.write_pod::<u64>(off + size as u64 - 8, tag ^ 0xFFFF);
            }
            live.push((off, tag, size));
        } else {
            let i = rng.gen_range(live.len() as u64) as usize;
            let (off, tag, size) = live.swap_remove(i);
            assert_eq!(a.read_pod::<u64>(off), tag, "step {step}: head corrupted");
            if size >= 16 {
                assert_eq!(
                    a.read_pod::<u64>(off + size as u64 - 8),
                    tag ^ 0xFFFF,
                    "step {step}: tail corrupted"
                );
            }
            a.deallocate(off).unwrap();
        }
        // periodically verify a sample of the live set
        if step % 64 == 0 {
            for &(off, tag, _) in live.iter().take(16) {
                assert_eq!(a.read_pod::<u64>(off), tag);
            }
        }
    }
    for (off, tag, _) in live {
        assert_eq!(a.read_pod::<u64>(off), tag);
        a.deallocate(off).unwrap();
    }
}

#[test]
fn fuzz_metall() {
    let d = TempDir::new("fz-metall");
    let opts = ManagerOptions {
        chunk_size: CHUNK,
        file_size: 1 << 20,
        vm_reserve: 4 << 30,
        ..Default::default()
    };
    let m = MetallManager::create_with(d.join("s"), opts).unwrap();
    fuzz_against_model(&m, 11, 6000, 4096);
    // include large allocations (> chunk/2)
    fuzz_against_model(&m, 12, 500, 3 * CHUNK);
    m.close().unwrap();
}

#[test]
fn fuzz_bip() {
    let d = TempDir::new("fz-bip");
    let a = BipAllocator::create_with(d.join("s"), seg_opts()).unwrap();
    fuzz_against_model(&a, 21, 6000, 4096);
    fuzz_against_model(&a, 22, 500, 3 * CHUNK);
}

#[test]
fn fuzz_pmemkind() {
    let d = TempDir::new("fz-pk");
    let a =
        PmemKindAllocator::create_with(d.join("s"), MadvMode::DontNeed, seg_opts(), CHUNK)
            .unwrap();
    fuzz_against_model(&a, 31, 6000, 4096);
    fuzz_against_model(&a, 32, 500, 3 * CHUNK);
}

#[test]
fn fuzz_ralloc() {
    let d = TempDir::new("fz-ra");
    let a = RallocLike::create_with(d.join("s"), seg_opts(), CHUNK).unwrap();
    fuzz_against_model(&a, 41, 6000, 4096);
    fuzz_against_model(&a, 42, 500, 3 * CHUNK);
}

#[test]
fn fuzz_metall_multithreaded() {
    let d = TempDir::new("fz-mt");
    let opts = ManagerOptions {
        chunk_size: CHUNK,
        file_size: 1 << 20,
        vm_reserve: 4 << 30,
        ..Default::default()
    };
    let m = MetallManager::create_with(d.join("s"), opts).unwrap();
    std::thread::scope(|s| {
        for t in 0..6 {
            let m = &m;
            s.spawn(move || fuzz_against_model(m, 100 + t, 3000, 2048));
        }
    });
    m.close().unwrap();
}

/// Internal-fragmentation invariant (paper §4.2): the class chosen for
/// any size wastes ≤ 25% (geometric region) and every offset returned is
/// aligned to 8.
#[test]
fn size_class_and_alignment_invariants() {
    let d = TempDir::new("fz-frag");
    let opts = ManagerOptions {
        chunk_size: CHUNK,
        file_size: 1 << 20,
        vm_reserve: 4 << 30,
        ..Default::default()
    };
    let m = MetallManager::create_with(d.join("s"), opts).unwrap();
    let mut rng = Xoshiro256ss::new(5);
    for _ in 0..2000 {
        let size = 8 + rng.gen_range(30_000) as usize;
        let off = m.allocate(size).unwrap();
        assert_eq!(off % 8, 0, "8-byte alignment");
        if size > 32 {
            let class = size_of_bin(bin_of(size));
            assert!((class - size) as f64 / class as f64 <= 0.25);
        }
        m.deallocate(off).unwrap();
    }
    m.close().unwrap();
}

/// After a full churn cycle the allocator must return all chunks —
/// i.e., no physical leak (checked through used_segment_bytes).
#[test]
fn no_space_leak_after_full_free() {
    let d = TempDir::new("fz-leak");
    let opts = ManagerOptions {
        chunk_size: CHUNK,
        file_size: 1 << 20,
        vm_reserve: 4 << 30,
        ..Default::default()
    };
    let m = MetallManager::create_with(d.join("s"), opts).unwrap();
    let mut offs = Vec::new();
    let mut rng = Xoshiro256ss::new(77);
    for _ in 0..3000 {
        offs.push(m.allocate(8 + rng.gen_range(2000) as usize).unwrap());
    }
    for off in offs {
        m.deallocate(off).unwrap();
    }
    // sync() preserves cache warmth; the explicit flush drains the
    // object caches to the bitsets so emptied chunks are released
    m.flush_object_caches().unwrap();
    m.sync().unwrap();
    assert_eq!(m.used_segment_bytes(), 0, "all chunks must return to Free");
    m.close().unwrap();
}

/// Property-based trace against a shadow oracle: 12k randomized
/// alloc/dealloc/realloc ops spanning every bin through multi-chunk large
/// allocations, checked against a `HashMap` of live allocations. Asserts
/// (a) no two live allocations overlap (byte-range check on every
/// mutation), (b) freed slots are reusable, (c) contents survive realloc
/// moves, and (d) every live offset is stable across a close/open cycle.
#[test]
fn property_trace_against_oracle() {
    const STEPS: usize = 12_000;
    let d = TempDir::new("fz-oracle");
    let store = d.join("s");
    let opts = ManagerOptions {
        chunk_size: CHUNK,
        file_size: 1 << 20,
        vm_reserve: 4 << 30,
        ..Default::default()
    };
    let m = MetallManager::create_with(&store, opts).unwrap();
    let mut rng = Xoshiro256ss::new(0x0_2ACE);
    // oracle: offset → (requested size, usable size, fill byte)
    let mut live: HashMap<u64, (usize, usize, u8)> = HashMap::new();
    let mut order: Vec<u64> = Vec::new(); // for random victim picks

    let usable_of = |m: &MetallManager, off: u64| m.usable_size(off).unwrap();
    let check_no_overlap = |live: &HashMap<u64, (usize, usize, u8)>, off: u64, usable: usize| {
        for (&o, &(_, u, _)) in live {
            let disjoint = off + usable as u64 <= o || o + u as u64 <= off;
            assert!(disjoint, "[{off}, +{usable}) overlaps [{o}, +{u})");
        }
    };
    let random_size = |rng: &mut Xoshiro256ss| -> usize {
        match rng.gen_range(100) {
            0..=69 => 1 + rng.gen_range(2048) as usize,            // all small bins
            70..=89 => 1 + rng.gen_range((CHUNK / 2) as u64) as usize, // up to max small
            _ => CHUNK / 2 + 1 + rng.gen_range((3 * CHUNK) as u64) as usize, // large
        }
    };

    // in-flight background epoch ticket: requested at one random trace
    // point, waited on at a later one — the trace keeps allocating,
    // freeing and reallocating while the flusher serializes
    let mut pending_ticket = None;
    for step in 0..STEPS {
        // periodic incremental syncs at arbitrary trace points: the
        // cache-preserving sync must never disturb allocator behaviour
        // (no drain → the LIFO warmth and therefore the trace's offset
        // sequence are unchanged), and every later assertion doubles as
        // a mid-trace-consistency check
        if step % 1711 == 1000 {
            m.sync().unwrap();
        }
        // random sync_async/wait points: request an epoch here, collect
        // its durability result hundreds of mutations later
        if step % 977 == 300 {
            if let Some(t) = pending_ticket.take() {
                t.wait().unwrap();
            }
            pending_ticket = Some(m.sync_async().unwrap());
        }
        match rng.gen_range(100) {
            // allocate
            0..=49 => {
                let size = random_size(&mut rng);
                let off = m.allocate(size).unwrap();
                let usable = usable_of(&m, off);
                assert!(usable >= size, "step {step}: usable {usable} < size {size}");
                check_no_overlap(&live, off, usable);
                let fill = (step % 251) as u8;
                unsafe { m.bytes_mut(off, size).fill(fill) };
                assert!(live.insert(off, (size, usable, fill)).is_none());
                order.push(off);
            }
            // deallocate
            50..=74 => {
                if order.is_empty() {
                    continue;
                }
                let i = rng.gen_range(order.len() as u64) as usize;
                let off = order.swap_remove(i);
                let (size, _, fill) = live.remove(&off).unwrap();
                let data = unsafe { m.bytes(off, size) };
                assert!(
                    data.iter().all(|&b| b == fill),
                    "step {step}: contents corrupted before free"
                );
                m.deallocate(off).unwrap();
            }
            // reallocate
            _ => {
                if order.is_empty() {
                    continue;
                }
                let i = rng.gen_range(order.len() as u64) as usize;
                let off = order[i];
                let (old_size, _, fill) = live.remove(&off).unwrap();
                let new_size = random_size(&mut rng);
                let new_off = m.reallocate(off, new_size).unwrap();
                let usable = usable_of(&m, new_off);
                assert!(usable >= new_size);
                check_no_overlap(&live, new_off, usable);
                let preserved = old_size.min(new_size);
                let data = unsafe { m.bytes(new_off, preserved) };
                assert!(
                    data.iter().all(|&b| b == fill),
                    "step {step}: realloc lost contents"
                );
                // refresh the fill over the full new extent
                let fill = (step % 251) as u8;
                unsafe { m.bytes_mut(new_off, new_size).fill(fill) };
                assert!(live.insert(new_off, (new_size, usable, fill)).is_none());
                order[i] = new_off;
            }
        }
    }

    // resolve the last in-flight background epoch before closing
    if let Some(t) = pending_ticket.take() {
        t.wait().unwrap();
    }

    // offsets and contents are stable across a close/open cycle
    m.close().unwrap();
    let m = MetallManager::open(&store).unwrap();
    for (&off, &(size, usable, fill)) in &live {
        assert_eq!(m.usable_size(off).unwrap(), usable, "offset {off} class stable");
        let data = unsafe { m.bytes(off, size) };
        assert!(data.iter().all(|&b| b == fill), "offset {off} contents stable");
    }
    // the allocator still works: everything frees, nothing leaks
    for &off in live.keys() {
        m.deallocate(off).unwrap();
    }
    m.flush_object_caches().unwrap();
    m.sync().unwrap();
    assert_eq!(m.used_segment_bytes(), 0, "full free returns every chunk");
    m.close().unwrap();
}

/// Cross-shard property trace: a 4-shard manager under an injected
/// 2-node topology, driven from one thread whose pinned vcpu — hence
/// home node *and* home shard — rotates every step, so objects are
/// routinely freed from a different shard (and node) than the one that
/// allocated them (remote-free queue path). Checked against a shadow
/// oracle; afterwards placement must be 100 % node-local (every chunk is
/// first-touched by its owner, wherever it is later freed from), the
/// store is reopened with 2 shards and then 1 shard (recovery re-deals
/// chunk ownership), contents are verified, and a full free must leak
/// nothing.
#[test]
fn cross_shard_property_trace_and_reshard_reopen() {
    const STEPS: usize = 6000;
    let d = TempDir::new("fz-xshard");
    let store = d.join("s");
    let opts = ManagerOptions {
        chunk_size: CHUNK,
        file_size: 1 << 20,
        vm_reserve: 4 << 30,
        shards: 4,
        // vcpus 0–1 on node 0, 2–3 on node 1: the rotating pin below
        // alternates nodes as well as shards
        topology: Some(Topology::fake(&[2, 2])),
        ..Default::default()
    };
    let m = MetallManager::create_with(&store, opts).unwrap();
    let mut rng = Xoshiro256ss::new(0x5A4D);
    // oracle: offset → (size, usable, tag)
    let mut live: HashMap<u64, (usize, usize, u64)> = HashMap::new();
    let mut order: Vec<u64> = Vec::new();
    for step in 0..STEPS {
        pin_thread_vcpu(Some(step % 4)); // rotate home shard
        if live.is_empty() || rng.next_f64() < 0.55 {
            // three hot classes so the per-(core, bin) caches overflow and
            // spill — the path that feeds the remote-free queues
            let size = 8usize << rng.gen_range(3); // 8, 16, 32
            let off = m.allocate(size).unwrap();
            let usable = m.usable_size(off).unwrap();
            assert!(usable >= size);
            for (&o, &(_, u, _)) in &live {
                let disjoint = off + usable as u64 <= o || o + u as u64 <= off;
                assert!(disjoint, "step {step}: [{off},+{usable}) overlaps [{o},+{u})");
            }
            let tag = rng.next_u64();
            m.write_pod::<u64>(off, tag);
            assert!(live.insert(off, (size, usable, tag)).is_none());
            order.push(off);
        } else {
            let i = rng.gen_range(order.len() as u64) as usize;
            let off = order.swap_remove(i);
            let (_, _, tag) = live.remove(&off).unwrap();
            assert_eq!(m.read_pod::<u64>(off), tag, "step {step}: corrupted before free");
            m.deallocate(off).unwrap();
        }
    }
    // deterministic cross-shard burst: allocate a batch on vcpu 0's home
    // shard (at most PER_BIN_CAP of these can come from the mixed-owner
    // cache; the rest are claims from that shard's own chunks), then free
    // it all from vcpu 1's home shard — a different shard under this
    // topology — so the spill must park foreign-owned slots on the
    // owner's remote queue
    pin_thread_vcpu(Some(0));
    let extra: Vec<u64> = (0..200).map(|_| m.allocate(8).unwrap()).collect();
    pin_thread_vcpu(Some(1));
    for &off in &extra {
        m.deallocate(off).unwrap();
    }
    pin_thread_vcpu(None);
    let ss = m.shard_stats();
    assert!(
        ss.iter().map(|s| s.remote_frees).sum::<u64>() > 0,
        "cross-shard burst must exercise the remote-free queue: {ss:?}"
    );
    // placement under the rotating node pins: every fresh chunk was
    // placed by exactly one layer (mbind when available, else zeroed by
    // its owning shard on its own node), so the report attributes 100 %
    // (≥ 95 % acceptance bar) node-local
    for s in &ss {
        assert_eq!(
            s.bound_chunks + s.first_touch_chunks,
            s.fresh_chunks,
            "shard {} bound or owner-touched",
            s.shard
        );
    }
    let r = m.placement_report();
    assert_eq!(r.accounted_pages(), r.total_pages, "report is total");
    for s in &r.per_shard {
        assert_eq!(s.remote_pages, 0, "shard {} node-local", s.shard);
        assert_eq!(s.unknown_pages, 0, "shard {} fully attributed", s.shard);
    }
    assert!(
        r.node_local_fraction().unwrap_or(0.0) >= 0.95,
        "≥95% node-local under rotating node pins: {r:?}"
    );
    m.close().unwrap();

    // reopen with fewer shards; every live object must be intact
    for reopen_shards in [2usize, 1] {
        let opts = ManagerOptions {
            chunk_size: CHUNK,
            file_size: 1 << 20,
            vm_reserve: 4 << 30,
            shards: reopen_shards,
            ..Default::default()
        };
        let m = MetallManager::open_with(&store, opts, false, false).unwrap();
        assert_eq!(m.num_shards(), reopen_shards);
        for (&off, &(_, usable, tag)) in &live {
            assert_eq!(m.read_pod::<u64>(off), tag, "shards={reopen_shards} offset {off}");
            assert_eq!(m.usable_size(off).unwrap(), usable, "class stable");
        }
        assert!(m.doctor().unwrap().is_empty());
        m.close().unwrap();
    }

    // full free under the final shard count: no leaked slots
    let m = MetallManager::open(&store).unwrap();
    for &off in live.keys() {
        m.deallocate(off).unwrap();
    }
    m.flush_object_caches().unwrap();
    m.sync().unwrap();
    assert_eq!(m.used_segment_bytes(), 0, "cross-shard churn leaked chunks");
    m.close().unwrap();
}

/// Placement-introspection contract: `placement_report()` is *total*
/// (every mapped page accounted exactly once), stays total and all-local
/// across a close/open cycle, and on single-node hosts attributes every
/// page to node 0.
#[test]
fn placement_report_total_stable_and_all_node0_on_single_node() {
    let d = TempDir::new("fz-placement");
    let store = d.join("s");
    let opts = ManagerOptions {
        chunk_size: CHUNK,
        file_size: 1 << 20,
        vm_reserve: 4 << 30,
        ..Default::default()
    };
    let m = MetallManager::create_with(&store, opts.clone()).unwrap();
    // a mix that populates every bucket: small chunks across bins, a
    // multi-chunk large allocation, and freed chunks
    let mut rng = Xoshiro256ss::new(0xBEEF);
    let mut live = Vec::new();
    for i in 0..400usize {
        let off = m.allocate(8 + rng.gen_range(2000) as usize).unwrap();
        if i % 3 == 0 {
            m.deallocate(off).unwrap();
        } else {
            live.push(off);
        }
    }
    let big = m.allocate(3 * CHUNK).unwrap();
    // a freed chunk-run guarantees the Free bucket is populated (large
    // frees release their chunks immediately, no cache in between)
    let filler = m.allocate(2 * CHUNK).unwrap();
    m.deallocate(filler).unwrap();
    let check_total = |m: &MetallManager| {
        let r = m.placement_report();
        let ps = page_size();
        assert_eq!(r.total_pages as usize, m.segment().mapped_len() / ps, "mapped coverage");
        assert_eq!(r.accounted_pages(), r.total_pages, "every page accounted once");
        if m.topology().num_nodes() == 1 {
            // all-node-0 on single-node hosts, wherever the data came from
            for s in &r.per_shard {
                assert_eq!(s.node, 0, "shard {} homed on node 0", s.shard);
                assert_eq!(s.remote_pages, 0, "shard {} nothing remote", s.shard);
                assert_eq!(
                    s.pages,
                    s.node_local_pages + s.unknown_pages,
                    "shard {} pages split local/unknown only",
                    s.shard
                );
            }
        }
        r
    };
    let before = check_total(&m);
    assert!(before.per_shard.iter().map(|s| s.pages).sum::<u64>() > 0, "live small chunks");
    assert!(before.large_pages > 0, "large bucket populated");
    assert!(before.free_pages > 0, "free bucket populated");
    m.close().unwrap();

    // totality and (single-node) locality are stable across close/open —
    // placement is DRAM-only, so reattach must rebuild a coherent view
    let m = MetallManager::open_with(&store, opts, false, false).unwrap();
    let after = check_total(&m);
    assert_eq!(after.total_pages, before.total_pages, "mapped extent stable");
    assert_eq!(
        after.per_shard.iter().map(|s| s.pages).sum::<u64>()
            + after.large_pages
            + after.free_pages,
        before.per_shard.iter().map(|s| s.pages).sum::<u64>()
            + before.large_pages
            + before.free_pages,
        "bucket totals stable across reattach"
    );
    m.deallocate(big).unwrap();
    for off in live {
        m.deallocate(off).unwrap();
    }
    m.flush_object_caches().unwrap();
    m.sync().unwrap();
    let drained = m.placement_report();
    assert_eq!(drained.accounted_pages(), drained.total_pages);
    assert_eq!(drained.per_shard.iter().map(|s| s.pages).sum::<u64>(), 0, "all chunks freed");
    m.close().unwrap();
}

/// Reattach equality: a randomized heap survives close/open bit-exactly.
#[test]
fn reattach_preserves_every_byte() {
    let d = TempDir::new("fz-reattach");
    let store = d.join("s");
    let mut model: HashMap<u64, Vec<u8>> = HashMap::new();
    {
        let opts = ManagerOptions {
            chunk_size: CHUNK,
            file_size: 1 << 20,
            vm_reserve: 4 << 30,
            ..Default::default()
        };
        let m = MetallManager::create_with(&store, opts).unwrap();
        let mut rng = Xoshiro256ss::new(123);
        for i in 0..500 {
            let size = 8 + rng.gen_range(1500) as usize;
            let off = m.allocate(size).unwrap();
            let data: Vec<u8> = (0..size).map(|j| ((i * j) % 251) as u8).collect();
            m.write_bytes(off, &data);
            model.insert(off, data);
        }
        m.close().unwrap();
    }
    let m = MetallManager::open(&store).unwrap();
    for (&off, data) in &model {
        let got = unsafe { m.bytes_at(off, data.len()) };
        assert_eq!(got, &data[..], "offset {off}");
    }
    // allocator still works after reattach and does not clobber old data
    let extra = m.allocate(64).unwrap();
    m.write_pod::<u64>(extra, 42);
    for (&off, data) in &model {
        let got = unsafe { m.bytes_at(off, data.len()) };
        assert_eq!(got, &data[..], "offset {off} after post-reattach alloc");
    }
    m.close().unwrap();
}

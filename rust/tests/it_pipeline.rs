//! Pipeline + experiment-driver integration: ingestion equivalence
//! across allocators, incremental monthly flow, and netfs-sim shape
//! checks (the assertions DESIGN.md §5 lists as "expected shapes").

use metall_rs::alloc::{ManagerOptions, MetallManager};
use metall_rs::baselines::bip::BipAllocator;
use metall_rs::containers::BankedAdjacency;
use metall_rs::coordinator::metrics::Metrics;
use metall_rs::coordinator::pipeline::{ingest, PipelineConfig};
use metall_rs::experiments::fig5::{run_cell, Fig5Params, IoMode};
use metall_rs::graph::rmat::RmatGenerator;
use metall_rs::storage::segment::SegmentOptions;
use metall_rs::util::tmp::TempDir;

fn small_fig5() -> Fig5Params {
    // Small enough for CI, but large enough that the store-size vs
    // dirty-sparsity regime matches the paper (a tiny store makes
    // staging's bulk copy artificially free and inverts the crossover).
    Fig5Params {
        months: 6,
        first_month_edges: 10_000,
        nbanks: 64,
        chunk_size: 64 << 10,
        file_size: 1 << 20,
    }
}

/// The same edge stream through Metall and BIP yields the identical
/// graph (allocator independence of the data structure).
#[test]
fn identical_graph_across_allocators() {
    let d = TempDir::new("xalloc");
    let edges = RmatGenerator::graph500(9, 8).seed(3).generate();
    let cfg = PipelineConfig { workers: 3, batch_size: 512, queue_depth: 4, nbanks: 64 };

    let m = MetallManager::create_with(d.join("m"), ManagerOptions::small_for_tests())
        .unwrap();
    let gm = BankedAdjacency::create(&m, 64).unwrap();
    ingest(&m, &gm, edges.iter().copied(), &cfg, true, &Metrics::new()).unwrap();

    let b = BipAllocator::create_with(
        d.join("b"),
        SegmentOptions::default().with_file_size(1 << 20).with_vm_reserve(4 << 30),
    )
    .unwrap();
    let gb = BankedAdjacency::create(&b, 64).unwrap();
    ingest(&b, &gb, edges.iter().copied(), &cfg, true, &Metrics::new()).unwrap();

    assert_eq!(gm.num_edges(&m), gb.num_edges(&b));
    assert_eq!(gm.num_vertices(&m), gb.num_vertices(&b));
    for v in 0..512u64 {
        let mut nm = gm.neighbors(&m, v);
        let mut nb = gb.neighbors(&b, v);
        nm.sort_unstable();
        nb.sort_unstable();
        assert_eq!(nm, nb, "vertex {v}");
    }
    m.close().unwrap();
}

/// Backpressure: a deep producer with a shallow queue still delivers
/// every edge exactly once.
#[test]
fn shallow_queue_backpressure_is_lossless() {
    let d = TempDir::new("bp");
    let m = MetallManager::create_with(d.join("s"), ManagerOptions::small_for_tests())
        .unwrap();
    let g = BankedAdjacency::create(&m, 16).unwrap();
    let cfg = PipelineConfig { workers: 1, batch_size: 16, queue_depth: 1, nbanks: 16 };
    let edges: Vec<(u64, u64)> = (0..5_000u64).map(|i| (i % 97, i % 89)).collect();
    let rep = ingest(&m, &g, edges.iter().copied(), &cfg, false, &Metrics::new()).unwrap();
    assert_eq!(rep.edges, 5_000);
    assert_eq!(g.num_edges(&m), 5_000);
    m.close().unwrap();
}

/// Fig 5 shape on VAST: bs-mmap beats staging (paper: 1.5–2.4x).
#[test]
fn vast_shape_bs_beats_staging() {
    let d = TempDir::new("vastshape");
    let p = small_fig5();
    let total = |mode| -> f64 {
        run_cell("vast", "reddit", mode, &p, d.path())
            .unwrap()
            .iter()
            .map(|r| r.ingest_secs + r.flush_secs)
            .sum()
    };
    let bs = total(IoMode::BsMmap);
    let staging = total(IoMode::StagingMmap);
    let direct = total(IoMode::DirectMmap);
    assert!(bs < staging, "VAST: bs-mmap {bs} must beat staging {staging}");
    assert!(bs < direct, "VAST: bs-mmap {bs} must beat direct {direct}");
}

/// Fig 5 shape on Lustre: staging wins; direct-mmap is the disaster
/// case (paper: "did not complete within a reasonable time").
#[test]
fn lustre_shape_staging_wins_direct_loses() {
    let d = TempDir::new("lustreshape");
    let p = small_fig5();
    let total = |mode| -> f64 {
        run_cell("lustre", "wiki", mode, &p, d.path())
            .unwrap()
            .iter()
            .map(|r| r.ingest_secs + r.flush_secs)
            .sum()
    };
    let bs = total(IoMode::BsMmap);
    let staging = total(IoMode::StagingMmap);
    let direct = total(IoMode::DirectMmap);
    assert!(
        direct > bs && direct > staging,
        "Lustre: direct-mmap ({direct}) must be worst (bs {bs}, staging {staging})"
    );
}

/// Monthly incremental run accumulates edges and every month's flush
/// leaves a cleanly reopenable store (exercised inside run_cell).
#[test]
fn incremental_months_accumulate() {
    let d = TempDir::new("months");
    let rows = run_cell("vast", "wiki", IoMode::BsMmap, &small_fig5(), d.path()).unwrap();
    assert_eq!(rows.len(), small_fig5().months as usize);
    assert!(rows[1].edges > rows[0].edges, "stream grows");
    for r in &rows {
        assert!(r.flush_secs > 0.0);
    }
}

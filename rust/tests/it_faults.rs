//! Fault-injection integration tests (robustness PR: deterministic
//! syscall-level failures via [`metall_rs::storage::faults`]).
//!
//! The headline test is an ALICE-style sweep: a fixed workload is first
//! dry-run under a counting plan to learn how many durability syscalls
//! (write / fsync / dirfsync / msync / ftruncate / rename / mmap /
//! reflink / lease) it issues, then re-run once per `k` with the k-th
//! I/O forced to fail. After every injected failure the store must
//! either have never been created, or reopen via `open_unclean()` with
//! a clean `doctor()` report and the container holding an exact prefix
//! of the workload's trace — never shorter than the last acknowledged
//! `sync()`.
//!
//! The remaining tests pin the failure-semantics contracts one by one:
//! ENOSPC on segment extension rolls back cleanly to `Error::Alloc`;
//! persistent flush failure wounds the manager into degraded read-only
//! while an attached reader keeps serving its pinned epoch; a full
//! op-log ring whose forced syncs are fault-stalled reports the stall
//! as `InvalidOp` after three attempts; a torn lease record makes the
//! pin scan conservatively pin everything.
//!
//! Fault state is process-global, so every test holds
//! [`faults::test_serial_guard`] for its whole body and disarms on exit
//! (panic included) via a drop guard.

use std::path::{Path, PathBuf};

use metall_rs::alloc::{
    readers, ManagerOptions, MetallManager, ReaderManager, SegmentAlloc, WOUNDED_MARKER,
};
use metall_rs::containers::oplog::{OpRecord, OP_VEC_PUSH};
use metall_rs::containers::PVec;
use metall_rs::error::Error;
use metall_rs::storage::faults::{self, FaultKind, FaultPlan, FaultReport, Site};
use metall_rs::telemetry::recorder;
use metall_rs::util::tmp::TempDir;

/// `small_for_tests` chunk size.
const CHUNK: usize = 64 << 10;

fn record_value(i: u64) -> u64 {
    i.wrapping_mul(7).wrapping_add(1)
}

/// Disarm on scope exit so a panicking test cannot leave a live plan
/// behind for the next test in the binary.
struct DisarmOnDrop;

impl Drop for DisarmOnDrop {
    fn drop(&mut self) {
        let _ = faults::disarm();
    }
}

/// Serialize the test body against every other fault test and clear any
/// state a previously panicked body left armed. Tuple order matters:
/// fields drop front-to-back, so the disarm runs while the serial lock
/// is still held.
fn serial() -> (DisarmOnDrop, std::sync::MutexGuard<'static, ()>) {
    let g = faults::test_serial_guard();
    let _ = faults::disarm();
    (DisarmOnDrop, g)
}

// ------------------------------------------------------- ALICE sweep --

/// What the sweep workload durably promised before it died: `floor` is
/// the record count covered by the last `sync()`/`close()` that
/// *returned Ok*, and is therefore the committed prefix recovery must
/// never roll back past.
#[derive(Default)]
struct Progress {
    created: bool,
    floor: u64,
    closed: bool,
}

/// The fixed workload under the sweep: create, batch pushes with two
/// explicit syncs in between, one multi-file large allocation (drives
/// segment-file create + truncate sites), clean close. Every fallible
/// step uses `?` so an injected fault surfaces exactly where it hit.
fn sweep_workload(store: &Path, p: &mut Progress) -> metall_rs::error::Result<()> {
    let m = MetallManager::create_with(store, ManagerOptions::small_for_tests())?;
    p.created = true;
    let v = PVec::<u64>::create(&m)?;
    m.construct::<u64>("log", v.offset())?;
    for i in 0..40 {
        v.push(&m, record_value(i))?;
    }
    m.sync()?;
    p.floor = 40;
    for i in 40..80 {
        v.push(&m, record_value(i))?;
    }
    // 20 chunks > one 1 MiB segment file: exercises file create +
    // ftruncate under fault, and the extend-rollback path on failure.
    let big = m.allocate(20 * CHUNK)?;
    m.deallocate(big)?;
    m.sync()?;
    p.floor = 80;
    for i in 80..120 {
        v.push(&m, record_value(i))?;
    }
    m.close()?;
    p.floor = 120;
    p.closed = true;
    Ok(())
}

/// Post-failure oracle: the store reopens via the explicit unclean
/// escape hatch, doctor is clean, and the vector is an exact
/// `record_value` prefix no shorter than the acknowledged floor.
fn recovery_oracle(store: &Path, p: &Progress, k: u64) {
    let m = MetallManager::open_unclean(store)
        .unwrap_or_else(|e| panic!("k={k}: created store must reopen uncleanly: {e}"));
    let findings = m.doctor().unwrap();
    assert!(findings.is_empty(), "k={k}: doctor after recovery: {findings:?}");
    let len = match m.find::<u64>("log").unwrap() {
        None => 0,
        Some(cell) => {
            let v = PVec::<u64>::from_offset(m.read(cell));
            let len = v.len(&m) as u64;
            for i in 0..len {
                assert_eq!(
                    v.get(&m, i as usize),
                    record_value(i),
                    "k={k}: corrupted record at index {i}"
                );
            }
            len
        }
    };
    assert!(len <= 120, "k={k}: recovered more records than were ever pushed: {len}");
    assert!(
        len >= p.floor,
        "k={k}: committed prefix lost: recovered {len} < acknowledged floor {}",
        p.floor
    );
    m.close().unwrap_or_else(|e| panic!("k={k}: re-seal after recovery failed: {e}"));
}

fn manifest_out_path() -> PathBuf {
    std::env::var_os("METALL_FAULTS_MANIFEST")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("target/it_faults_failure_sites.json"))
}

/// Persist the per-site failure-site manifest (CI uploads it as an
/// artifact): which sites the workload exercises and how often, plus
/// the sweep outcome tallies.
fn write_site_manifest(seed: u64, dry: &FaultReport, recovered: u64, skipped: u64) {
    let path = manifest_out_path();
    if let Some(parent) = path.parent() {
        let _ = std::fs::create_dir_all(parent);
    }
    let mut sites = String::new();
    for s in Site::ALL {
        if !sites.is_empty() {
            sites.push(',');
        }
        sites.push_str(&format!("\"{}\":{}", s.name(), dry.site_ops[s as usize]));
    }
    let body = format!(
        "{{\"seed\":{seed},\"total_ops\":{},\"sites\":{{{sites}}},\
         \"sweep_runs\":{},\"recovered\":{recovered},\"skipped_precreate\":{skipped}}}\n",
        dry.ops, dry.ops
    );
    let _ = std::fs::write(&path, body);
}

#[test]
fn alice_sweep_every_kth_io_failure_preserves_committed_prefix() {
    let _serial = serial();
    let seed: u64 = std::env::var("METALL_FAULTS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xFA17);

    // Dry run: count every durability syscall the workload issues.
    faults::arm_counting_process_wide();
    let dry = {
        let d = TempDir::new("faults-dry");
        let mut p = Progress::default();
        sweep_workload(&d.path().join("s"), &mut p).expect("fault-free dry run");
        assert!(p.closed);
        faults::disarm()
    };
    assert_eq!(dry.injected, 0);
    assert!(
        dry.ops >= 20,
        "workload must exercise a meaningful number of I/O sites, saw {}",
        dry.ops
    );
    assert!(dry.ops <= 2000, "sweep would be unreasonably large: {} ops", dry.ops);
    write_site_manifest(seed, &dry, 0, 0);
    let n = dry.ops;

    // Sweep: fail the k-th I/O for every k, rotating the injected
    // errno by the seed so EIO / ENOSPC / torn write / EAGAIN all land
    // on many different sites across the sweep.
    const KINDS: [FaultKind; 4] =
        [FaultKind::Eio, FaultKind::Enospc, FaultKind::ShortWrite, FaultKind::Eagain];
    let (mut recovered, mut skipped) = (0u64, 0u64);
    for k in 1..=n {
        let kind = KINDS[(k.wrapping_add(seed) % 4) as usize];
        let d = TempDir::new(&format!("faults-sweep-{k}"));
        let store = d.path().join("s");
        let mut p = Progress::default();
        faults::arm_process_wide(FaultPlan::nth_global(k, kind));
        let res = sweep_workload(&store, &mut p);
        let rep = faults::disarm();
        if rep.injected == 0 {
            // Thread-timing variance moved the k-th op off this run:
            // then nothing failed and the workload must have succeeded.
            assert!(res.is_ok(), "k={k}: no fault injected yet workload failed: {res:?}");
        }
        if !p.created {
            // The fault killed `create_with` itself: nothing was
            // promised, nothing to recover.
            skipped += 1;
            continue;
        }
        recovery_oracle(&store, &p, k);
        recovered += 1;
    }
    assert!(recovered > 0, "sweep never reached a recoverable store");
    write_site_manifest(seed, &dry, recovered, skipped);
}

// --------------------------------------------- ENOSPC alloc rollback --

#[test]
fn enospc_on_segment_extension_rolls_back_to_alloc_error() {
    let _serial = serial();
    let d = TempDir::new("faults-enospc");
    let store = d.path().join("s");
    let m = MetallManager::create_with(&store, ManagerOptions::small_for_tests()).unwrap();
    let v = PVec::<u64>::create(&m).unwrap();
    m.construct::<u64>("log", v.offset()).unwrap();
    v.push(&m, record_value(0)).unwrap();

    // Every ftruncate/fallocate from here on reports a full disk (the
    // plan is thread-scoped: the extend-outside-lock path runs on the
    // allocating thread).
    faults::arm(FaultPlan::sticky_at(1, Site::Truncate, FaultKind::Enospc));

    // Large path: 20 chunks need a second segment file → extension
    // fails → reserved chunk run must return to the free pool and the
    // caller sees a clean allocation error, not an aborted process.
    match m.allocate(20 * CHUNK) {
        Err(Error::Alloc(msg)) => {
            assert!(msg.contains("no space"), "ENOSPC not surfaced in message: {msg}")
        }
        other => panic!("expected Error::Alloc from ENOSPC extension, got {other:?}"),
    }

    // Small path: fresh half-chunk allocations burn through the already
    // mapped file, then the first one needing a new file fails the same
    // way.
    let mut hit_small = false;
    for _ in 0..200 {
        match m.allocate(CHUNK / 2) {
            Ok(_) => continue,
            Err(Error::Alloc(_)) => {
                hit_small = true;
                break;
            }
            Err(e) => panic!("expected Error::Alloc on small-path ENOSPC, got {e:?}"),
        }
    }
    assert!(hit_small, "small allocations never hit the faulted extension");
    let _ = faults::disarm();

    // Inline allocation failures never wound the store, and both failed
    // extensions released their chunk reservations.
    assert!(!m.is_degraded());
    let hs = m.health_stats();
    assert!(hs.extend_rollbacks >= 2, "expected both rollbacks counted: {hs:?}");

    // With the disk "back", the same allocations succeed and the store
    // is still fully healthy.
    m.allocate(CHUNK / 2).expect("allocation after ENOSPC clears");
    m.allocate(20 * CHUNK).expect("large allocation after ENOSPC clears");
    v.push(&m, record_value(1)).unwrap();
    assert!(m.doctor().unwrap().is_empty());
    m.close().unwrap();
}

// ----------------------------------------- wounded mode + live reader --

#[test]
fn persistent_flush_failure_wounds_manager_while_reader_serves_pinned_epoch() {
    let _serial = serial();
    let d = TempDir::new("faults-wound");
    let store = d.path().join("s");
    let mut opts = ManagerOptions::small_for_tests();
    opts.sync_fail_limit = 2;
    let m = MetallManager::create_with(&store, opts).unwrap();
    let v = PVec::<u64>::create(&m).unwrap();
    m.construct::<u64>("log", v.offset()).unwrap();
    for i in 0..50 {
        v.push(&m, record_value(i)).unwrap();
    }
    m.sync().unwrap();

    // A reader pins the committed epoch before the backend "fails".
    let r = ReaderManager::attach(&store).unwrap();
    let roff = r.find::<u64>("log").unwrap().unwrap();
    assert_eq!(PVec::<u64>::from_offset(r.read(roff)).len(&r), 50);

    // Dirty the store, then make every fsync fail persistently. Two
    // consecutive failed flush rounds (sync_fail_limit) must wound the
    // manager into degraded read-only.
    for i in 50..60 {
        v.push(&m, record_value(i)).unwrap();
    }
    faults::arm_process_wide(FaultPlan::sticky_at(1, Site::Fsync, FaultKind::Eio));
    let mut wounded = false;
    for _ in 0..20 {
        let _ = m.sync();
        if m.is_degraded() {
            wounded = true;
            break;
        }
    }
    let _ = faults::disarm();
    assert!(wounded, "persistent fsync failure never wounded the manager");

    // Every mutating API now reports the degradation with attribution.
    let reason = m.degraded_reason().expect("wounded manager has a reason");
    assert!(
        reason.contains("consecutive failed flush rounds"),
        "unexpected wound attribution: {reason}"
    );
    assert!(matches!(m.allocate(64), Err(Error::Degraded(_))));
    assert!(matches!(m.sync(), Err(Error::Degraded(_))));
    assert!(matches!(v.push(&m, 0), Err(Error::Degraded(_))));
    let hs = m.health_stats();
    assert!(hs.degraded);
    assert!(hs.transient_failures >= 2, "failed rounds not counted: {hs:?}");
    let findings = m.doctor().unwrap();
    assert!(
        findings.iter().any(|f| f.contains("wounded")),
        "doctor must surface the wound: {findings:?}"
    );

    // The attached reader is untouched: it keeps serving the last
    // committed epoch.
    assert_eq!(PVec::<u64>::from_offset(r.read(roff)).len(&r), 50);

    // close() refuses the CLEAN marker and leaves the WOUNDED
    // breadcrumb for the next opener.
    assert!(matches!(m.close(), Err(Error::Degraded(_))));
    assert!(!store.join("CLEAN").exists(), "a wounded store must not be sealed CLEAN");
    let breadcrumb = store.join(WOUNDED_MARKER);
    assert!(breadcrumb.exists());
    assert!(std::fs::read_to_string(&breadcrumb).unwrap().contains("flush rounds"));

    // The wound must leave a parseable flight-recorder dump whose tail
    // attributes the failure: the failed flush rounds and the wound
    // itself, in that order.
    let dump_path = recorder::newest_dump(&store).expect("wound left no flight dump in diag/");
    let dump = recorder::load(&dump_path).expect("flight dump must parse after a wound");
    assert_eq!(dump.pid, std::process::id(), "dump must belong to the wounded owner");
    let kinds: Vec<u32> = dump.events.iter().map(|e| e.kind).collect();
    let first_failure = kinds
        .iter()
        .position(|&k| k == recorder::EventKind::FlushFailure as u32)
        .expect("flight dump records no FlushFailure event");
    let wound_at = kinds
        .iter()
        .position(|&k| k == recorder::EventKind::Wound as u32)
        .expect("flight dump records no Wound event");
    assert!(
        first_failure < wound_at,
        "failure events must precede the wound: {kinds:?}"
    );
    let wound_ev = dump.events[wound_at];
    assert!(
        wound_ev.a >= 2,
        "wound event must carry the consecutive-failure count: {wound_ev:?}"
    );
    assert!(
        wound_ev.describe().contains("degraded read-only"),
        "wound event must render an attribution line: {}",
        wound_ev.describe()
    );
    drop(r);

    // Recovery: the explicit unclean open clears the breadcrumb and
    // lands on the last committed manifest — the 50 acknowledged
    // records, not the 10 that never flushed.
    let m2 = MetallManager::open_unclean(&store).unwrap();
    assert!(!m2.is_degraded());
    assert!(!breadcrumb.exists(), "rw reopen must clear the WOUNDED breadcrumb");
    let off = m2.find::<u64>("log").unwrap().unwrap();
    let v2 = PVec::<u64>::from_offset(m2.read(off));
    let len = v2.len(&m2) as u64;
    assert!(len >= 50, "committed prefix lost across the wound: {len}");
    for i in 0..len.min(50) {
        assert_eq!(v2.get(&m2, i as usize), record_value(i));
    }
    assert!(m2.doctor().unwrap().is_empty());
    m2.close().unwrap();
}

// ------------------------------------- op-log ring-full stall contract --

#[test]
fn oplog_full_ring_with_fault_stalled_syncs_reports_invalid_op() {
    let _serial = serial();
    let d = TempDir::new("faults-ring");
    let store = d.path().join("s");
    let m = MetallManager::create_with(&store, ManagerOptions::small_for_tests()).unwrap();

    // One operation begins and never commits: it pins the reclaim
    // horizon at sequence 0 forever.
    let stalled = SegmentAlloc::oplog_begin(&m, OpRecord::new(OP_VEC_PUSH))
        .unwrap()
        .expect("manager-backed op log always issues tokens");
    // Fill the rest of the ring with committed records.
    for _ in 0..1023 {
        let t = SegmentAlloc::oplog_begin(&m, OpRecord::new(OP_VEC_PUSH)).unwrap();
        SegmentAlloc::oplog_commit(&m, t).unwrap();
    }

    // The ring is full and the forced syncs cannot help anyway: every
    // manifest rename fails. After three tolerated attempts the append
    // must report the stall instead of spinning.
    faults::arm_process_wide(FaultPlan::sticky_at(1, Site::Rename, FaultKind::Eio));
    let err = SegmentAlloc::oplog_begin(&m, OpRecord::new(OP_VEC_PUSH)).unwrap_err();
    let _ = faults::disarm();
    match &err {
        Error::InvalidOp(msg) => {
            assert!(msg.contains("stalled in flight"), "wrong stall message: {msg}")
        }
        other => panic!("expected InvalidOp from the full-ring stall, got {other:?}"),
    }
    let st = m.oplog_stats();
    assert_eq!(st.forced_syncs, 3, "exactly three forced syncs before giving up: {st:?}");
    assert_eq!(st.forced_sync_errors, 3, "all three were fault-stalled: {st:?}");
    // Three transient failures are far below the default wound limit.
    assert!(!m.is_degraded(), "a reported stall must not wound the store");

    // Committing the stalled op unblocks everything: the next sync
    // advances the horizon and appends work again.
    SegmentAlloc::oplog_commit(&m, Some(stalled)).unwrap();
    m.sync().unwrap();
    let t = SegmentAlloc::oplog_begin(&m, OpRecord::new(OP_VEC_PUSH)).unwrap();
    SegmentAlloc::oplog_commit(&m, t).unwrap();
    m.close().unwrap();
}

// -------------------------------------------------- torn lease record --

#[test]
fn torn_lease_record_makes_pin_scan_conservative() {
    let _serial = serial();
    let d = TempDir::new("faults-lease");
    let store = d.path().join("s");
    let m = MetallManager::create_with(&store, ManagerOptions::small_for_tests()).unwrap();
    m.construct::<u64>("x", 1).unwrap();
    m.sync().unwrap();

    let mut lease = readers::ReaderLease::acquire(&store).unwrap();
    // Tear the pin record mid-write: half of the 24-byte record lands
    // over the previous (valid) one.
    faults::arm(FaultPlan::nth_at(1, Site::Lease, FaultKind::ShortWrite));
    lease.pin(1).expect_err("torn lease write must surface the error");
    let _ = faults::disarm();

    // The lease is live (its flock is held) but undecodable: the scan
    // must refuse to guess and pin every epoch, so GC deletes nothing.
    let scan = readers::scan_pins(&store);
    assert_eq!(scan.live, 1, "the torn lease is still live: {scan:?}");
    assert!(scan.pin_all, "a torn lease record must pin everything: {scan:?}");

    // A successful re-pin repairs the record and the scan resolves.
    lease.pin(1).expect("re-pin over the torn record");
    let scan = readers::scan_pins(&store);
    assert_eq!(scan.live, 1);
    assert!(!scan.pin_all, "repaired lease must pin only its epoch: {scan:?}");
    assert_eq!(scan.epochs, vec![1]);

    drop(lease);
    m.close().unwrap();
}

//! Crash-injection integration tests (paper §3.3 CLEAN-marker protocol).
//!
//! A child process — this very test binary re-executed with a filter for
//! `crash_child_entry` and control env vars — mutates a datastore and
//! `SIGKILL`s itself at a randomized (seeded, deterministic) operation
//! index, memento-`crash_recovery.sh`-style but in pure Rust. The parent
//! then asserts the recovery contract:
//!
//! - a store that was **not** closed cleanly is refused by `open()`,
//! - the pre-crash **snapshot** opens cleanly and holds exactly the
//!   snapshotted state,
//! - `open_unclean()` is the explicit opt-in escape hatch, and closing it
//!   re-seals the store,
//! - a child that closes cleanly produces a store that reattaches with
//!   all data.

use std::path::{Path, PathBuf};
use std::process::Command;

use metall_rs::alloc::{pin_thread_vcpu, ManagerOptions, MetallManager};
use metall_rs::containers::{BankedAdjacency, PHashMapU64, PVec};
use metall_rs::coordinator::cli;
use metall_rs::numa::Topology;
use metall_rs::telemetry::recorder;
use metall_rs::util::rng::Xoshiro256ss;
use metall_rs::util::tmp::TempDir;

const MODE_ENV: &str = "METALL_IT_CRASH_MODE";
const DIR_ENV: &str = "METALL_IT_CRASH_DIR";
const KILL_AT_ENV: &str = "METALL_IT_CRASH_KILL_AT";

/// Records pushed before the snapshot is taken.
const BASE_RECORDS: u64 = 200;

fn record_value(i: u64) -> u64 {
    i.wrapping_mul(7).wrapping_add(1)
}

/// Child-process body: build a store, snapshot it, keep mutating, die.
/// Runs only when the control env vars are present; as a plain member of
/// the suite it is a no-op.
#[test]
fn crash_child_entry() {
    let mode = match std::env::var(MODE_ENV) {
        Ok(m) => m,
        Err(_) => return, // normal test run: nothing to do
    };
    let dir = PathBuf::from(std::env::var(DIR_ENV).expect("child needs dir"));
    let kill_at: u64 = std::env::var(KILL_AT_ENV).expect("child needs kill_at").parse().unwrap();

    // container-level crash modes have their own child bodies (the
    // generic trace below churns raw allocations; these churn the
    // op-logged containers themselves)
    match mode.as_str() {
        "crash-container" => return crash_container_child(&dir, kill_at),
        "kpoint-vec" | "kpoint-map" => return kill_point_child(&dir, &mode),
        _ => {}
    }

    let store = dir.join("s");
    // the "*-shards4" modes run the same trace on a 4-shard manager with
    // the home shard rotating per op (cross-shard alloc/free traffic);
    // "crash-numa2" additionally injects a fake 2-node topology so the
    // rotation crosses nodes and every fresh chunk goes through the
    // bind + owner-first-touch placement path before the kill;
    // "crash-sync" runs an incremental sync() every few ops so a random
    // kill point lands inside (or right around) a segmented sync —
    // section writes, manifest commit, GC — with high probability;
    // "crash-bgsync" never calls sync() in the churn loop at all: a tiny
    // dirty-byte watermark (+ interval timer) keeps the *background*
    // flusher committing epochs under continuous ingest, so the kill
    // lands around flushes nobody on the mutation path asked for;
    // "crash-pipeline" runs the depth-2 epoch-pipelined engine against
    // the simulated lustre backend (partly slept, so each commit takes
    // long enough for the next cut to queue behind it) and fires
    // sync_async every other op — the kill lands while epoch N's commit
    // is in flight and epoch N+1's sections sit serialized in the queue
    let numa = mode == "crash-numa2";
    let sharded = mode.ends_with("shards4") || numa;
    let syncy = mode == "crash-sync";
    let bgsync = mode == "crash-bgsync";
    let pipeline = mode == "crash-pipeline";
    let mut opts = ManagerOptions::small_for_tests();
    if sharded {
        opts.shards = 4;
    }
    if numa {
        opts.topology = Some(Topology::fake(&[2, 2]));
    }
    if bgsync {
        // one dirty 64 KiB chunk crosses the watermark; the timer mops
        // up management-only dirt between data bursts
        opts.sync_watermark_bytes = opts.chunk_size;
        opts.sync_interval_ms = 5;
    }
    if pipeline {
        opts.sync_pipeline_depth = 2;
        opts.netfs_profile = Some("lustre".to_string());
        // sleep a fifth of the modelled backend time: commits take long
        // enough that cuts queue behind them, and the SIGKILL window
        // reliably covers an overlapped prepare/commit pair
        opts.netfs_sleep_scale = 0.2;
    }
    let m = MetallManager::create_with(&store, opts).unwrap();
    let v = PVec::<u64>::create(&m).unwrap();
    m.construct::<u64>("log", v.offset()).unwrap();
    for i in 0..BASE_RECORDS {
        if sharded {
            pin_thread_vcpu(Some((i % 4) as usize));
        }
        v.push(&m, record_value(i)).unwrap();
    }
    m.snapshot(dir.join("snap")).unwrap();

    // "crash-sync"/"crash-bgsync": a timer thread delivers SIGKILL a few
    // ms from now, so the signal lands wherever the churn loop happens
    // to be — for "crash-sync" with a sync every 3 ops (each doing
    // section writes, fsyncs, a manifest rename and GC) that is usually
    // *inside* the segmented write path; for "crash-bgsync" it races the
    // watermark-driven background flusher instead. Armed only after the
    // snapshot completed: the snapshot is the recovery baseline the
    // parent asserts on.
    if syncy || bgsync || pipeline {
        let delay = std::time::Duration::from_millis(4 + kill_at % 60);
        std::thread::spawn(move || {
            std::thread::sleep(delay);
            unsafe { libc::raise(libc::SIGKILL) };
        });
    }

    // post-snapshot churn: pushes plus alloc/free noise, then die (or
    // close cleanly) at the controlled op index ("crash-sync" loops until
    // its timer fires instead)
    let mut scratch: Vec<u64> = Vec::new();
    for op in 0.. {
        if sharded {
            pin_thread_vcpu(Some((op % 4) as usize));
        }
        if !syncy && !bgsync && !pipeline && op == kill_at {
            match mode.as_str() {
                "clean" => {
                    m.construct::<u64>("post_ops", op).unwrap();
                    m.close().unwrap();
                    return;
                }
                _ => unsafe {
                    libc::raise(libc::SIGKILL);
                },
            }
        }
        let i = BASE_RECORDS + op;
        v.push(&m, record_value(i)).unwrap();
        if op % 3 == 0 {
            scratch.push(m.allocate(8 + (op as usize % 300)).unwrap());
        }
        if op % 5 == 0 {
            if let Some(off) = scratch.pop() {
                m.deallocate(off).unwrap();
            }
        }
        if syncy && op % 3 == 2 {
            m.sync().unwrap();
        }
        if pipeline && op % 2 == 1 {
            // fire-and-forget: tickets coalesce, the queue fills, and the
            // slowed commits keep two epochs in flight almost constantly
            drop(m.sync_async().unwrap());
        }
    }
    unreachable!("loop only exits through close or SIGKILL");
}

/// Re-exec this test binary as the crash child.
fn spawn_child(mode: &str, dir: &Path, kill_at: u64) -> std::process::ExitStatus {
    let exe = std::env::current_exe().expect("test binary path");
    Command::new(exe)
        .args(["crash_child_entry", "--exact", "--nocapture", "--test-threads=1"])
        .env(MODE_ENV, mode)
        .env(DIR_ENV, dir)
        .env(KILL_AT_ENV, kill_at.to_string())
        .status()
        .expect("spawn crash child")
}

fn assert_snapshot_intact(snap: &Path) {
    let s = MetallManager::open(snap).expect("snapshot must open cleanly");
    let off = s.find::<u64>("log").unwrap().expect("named object survives");
    let v = PVec::<u64>::from_offset(s.read(off));
    assert_eq!(v.len(&s), BASE_RECORDS as usize, "exactly the snapshotted records");
    for i in 0..BASE_RECORDS {
        assert_eq!(v.get(&s, i as usize), record_value(i), "record {i}");
    }
    assert!(s.doctor().unwrap().is_empty(), "snapshot is healthy");
    s.close().unwrap();
}

#[test]
fn kill9_mid_mutation_dirty_store_refused_snapshot_recovers() {
    use std::os::unix::process::ExitStatusExt;
    let mut rng = Xoshiro256ss::new(0xC4A5);
    for round in 0..3 {
        let d = TempDir::new(&format!("crash-inj-{round}"));
        let kill_at = rng.gen_range(400); // randomized kill point, seeded
        let status = spawn_child("crash", d.path(), kill_at);
        assert_eq!(
            status.signal(),
            Some(libc::SIGKILL),
            "round {round}: child must die by SIGKILL, got {status:?}"
        );

        let store = d.join("s");
        assert!(
            !store.join("CLEAN").exists(),
            "round {round}: no CLEAN marker after kill -9"
        );
        // 0. the dead owner left a parseable flight-recorder dump (the
        //    ring is mmap(MAP_SHARED), so kill -9 cannot lose it), and
        //    `metall trace` renders it. Snapshot the path *before* any
        //    reopen so it is provably the child's, not ours.
        let dump_path = recorder::newest_dump(&store)
            .unwrap_or_else(|| panic!("round {round}: kill -9 left no flight dump"));
        let dump = recorder::load(&dump_path)
            .unwrap_or_else(|e| panic!("round {round}: flight dump unparseable: {e}"));
        assert_ne!(
            dump.pid,
            std::process::id(),
            "round {round}: dump must belong to the dead child"
        );
        assert!(
            dump.events.iter().any(|e| e.kind == recorder::EventKind::Open as u32),
            "round {round}: child's dump must record its open"
        );
        assert!(
            !recorder::render_tail(&dump, 8).is_empty(),
            "round {round}: rendered tail must not be empty"
        );
        let trace_rc = cli::run(&[
            "trace".to_string(),
            "--store".to_string(),
            store.display().to_string(),
        ])
        .expect("metall trace runs on a crashed store");
        assert_eq!(trace_rc, 0, "round {round}: metall trace must render the dump");
        // 1. the dirty store is refused
        let err = match MetallManager::open(&store) {
            Err(e) => e,
            Ok(_) => panic!("round {round}: dirty store must be refused"),
        };
        let msg = format!("{err}");
        assert!(msg.contains("not closed cleanly"), "round {round}: {msg}");
        // 2. the pre-crash snapshot recovers the snapshotted state
        assert_snapshot_intact(&d.join("snap"));
        // 3. open_unclean is the explicit escape hatch; closing re-seals
        {
            let m = MetallManager::open_unclean(&store)
                .expect("open_unclean must accept the dirty store");
            let _ = m.doctor().expect("doctor runs on a recovered store");
            m.close().unwrap();
        }
        MetallManager::open(&store).expect("re-sealed store opens").close().unwrap();
    }
}

#[test]
fn clean_close_child_reattaches_with_all_data() {
    let d = TempDir::new("crash-clean");
    let post_ops = 123u64;
    let status = spawn_child("clean", d.path(), post_ops);
    assert!(status.success(), "clean child exits 0: {status:?}");

    let store = d.join("s");
    assert!(store.join("CLEAN").exists(), "clean close leaves the marker");
    let m = MetallManager::open(&store).unwrap();
    let v = PVec::<u64>::from_offset(m.read(m.find::<u64>("log").unwrap().unwrap()));
    let total = BASE_RECORDS + post_ops;
    assert_eq!(v.len(&m), total as usize, "base + post-snapshot records");
    for i in 0..total {
        assert_eq!(v.get(&m, i as usize), record_value(i), "record {i}");
    }
    assert_eq!(
        m.read::<u64>(m.find::<u64>("post_ops").unwrap().unwrap()),
        post_ops
    );
    assert!(m.doctor().unwrap().is_empty());
    m.close().unwrap();
    // the snapshot taken mid-run is still independently intact
    assert_snapshot_intact(&d.join("snap"));
}

/// Recovery with a different shard count: a 4-shard child (home shard
/// rotating per op, so chunks belong to all four shards) snapshots and is
/// kill-9ed; the snapshot must reopen with 1 and 2 shards — ownership is
/// re-dealt deterministically — with the property-trace oracle
/// (`record_value`) still matching every record.
#[test]
fn kill9_with_4_shards_snapshot_reopens_with_fewer_shards() {
    use std::os::unix::process::ExitStatusExt;
    let d = TempDir::new("crash-shards");
    let status = spawn_child("crash-shards4", d.path(), 150);
    assert_eq!(status.signal(), Some(libc::SIGKILL), "child dies by SIGKILL: {status:?}");

    let store = d.join("s");
    assert!(!store.join("CLEAN").exists());
    assert!(MetallManager::open(&store).is_err(), "dirty store refused");
    // the snapshot was written by a 4-shard manager; reopen with fewer
    for shards in [1usize, 2] {
        let mut o = ManagerOptions::small_for_tests();
        o.shards = shards;
        let s = MetallManager::open_with(d.join("snap"), o, false, false)
            .unwrap_or_else(|e| panic!("snapshot must reopen with {shards} shards: {e}"));
        assert_eq!(s.num_shards(), shards);
        let off = s.find::<u64>("log").unwrap().expect("named object survives");
        let v = PVec::<u64>::from_offset(s.read(off));
        assert_eq!(v.len(&s), BASE_RECORDS as usize, "shards={shards}");
        for i in 0..BASE_RECORDS {
            assert_eq!(v.get(&s, i as usize), record_value(i), "shards={shards} record {i}");
        }
        assert!(s.doctor().unwrap().is_empty(), "snapshot healthy at {shards} shards");
        s.close().unwrap();
    }
    // and the default (auto-shard) open still accepts it
    assert_snapshot_intact(&d.join("snap"));
}

/// Placement is DRAM-only state, exactly like the shard count: a store
/// mutated under an injected 2-node topology (fresh chunks bound and
/// owner-first-touched across both fake nodes) and kill-9ed must leave a
/// refused dirty store whose pre-crash snapshot reopens cleanly under an
/// explicit *1-node* topology — nothing about placement may leak into
/// the persistent image.
#[test]
fn kill9_under_fake_2node_topology_reopens_on_1node() {
    use std::os::unix::process::ExitStatusExt;
    let d = TempDir::new("crash-numa");
    let status = spawn_child("crash-numa2", d.path(), 120);
    assert_eq!(status.signal(), Some(libc::SIGKILL), "child dies by SIGKILL: {status:?}");

    let store = d.join("s");
    assert!(!store.join("CLEAN").exists());
    assert!(MetallManager::open(&store).is_err(), "dirty store refused");
    for shards in [1usize, 2] {
        let mut o = ManagerOptions::small_for_tests();
        o.shards = shards;
        o.topology = Some(Topology::fake(&[4])); // single node, explicitly
        let s = MetallManager::open_with(d.join("snap"), o, false, false).unwrap_or_else(|e| {
            panic!("2-node-written snapshot must reopen on 1 node with {shards} shards: {e}")
        });
        assert_eq!(s.num_shards(), shards);
        assert_eq!(s.topology().num_nodes(), 1);
        let off = s.find::<u64>("log").unwrap().expect("named object survives");
        let v = PVec::<u64>::from_offset(s.read(off));
        assert_eq!(v.len(&s), BASE_RECORDS as usize, "shards={shards}");
        for i in 0..BASE_RECORDS {
            assert_eq!(v.get(&s, i as usize), record_value(i), "shards={shards} record {i}");
        }
        assert!(s.doctor().unwrap().is_empty(), "snapshot healthy on 1 node");
        // the reopened view is total and trivially node-local: birth
        // records died with the crashed process, and on one node that
        // costs nothing
        let r = s.placement_report();
        assert_eq!(r.accounted_pages(), r.total_pages, "report total after reopen");
        for sp in &r.per_shard {
            assert_eq!(sp.node, 0);
            assert_eq!(sp.remote_pages, 0);
        }
        s.close().unwrap();
    }
    // and the default (auto-topology) open still accepts it
    assert_snapshot_intact(&d.join("snap"));
}

/// Kill-9 around *frequent incremental syncs* (the segmented-management
/// write path: section files, manifest commit, GC all in flight when the
/// signal lands). The recovery contract for a torn sync:
///
/// - the dirty store is still refused by plain `open()`,
/// - `open_unclean()` always succeeds — a torn newest manifest falls
///   back to the previous complete one; a torn section file invalidates
///   the manifest referencing it, never the fallback — and the recovered
///   store is structurally consistent (doctor clean),
/// - the recovered store keeps working (allocate/construct/close) and
///   then reopens cleanly, and the pre-churn snapshot is intact.
///
/// Post-sync *data* carries no guarantee after a kill (paper §3.3: work
/// on a duplicate); what must hold is management-level consistency.
#[test]
fn kill9_mid_incremental_sync_recovers_from_last_complete_manifest() {
    use std::os::unix::process::ExitStatusExt;
    let mut rng = Xoshiro256ss::new(0x5EC7);
    for round in 0..3 {
        let d = TempDir::new(&format!("crash-sync-{round}"));
        // the child syncs every 3 ops and a timer SIGKILLs it a few
        // (seeded-random) ms into the churn — the signal usually lands
        // inside a segmented sync's section writes / manifest commit / GC
        let kill_at = 3 + rng.gen_range(200);
        let status = spawn_child("crash-sync", d.path(), kill_at);
        assert_eq!(
            status.signal(),
            Some(libc::SIGKILL),
            "round {round}: child must die by SIGKILL, got {status:?}"
        );
        let store = d.join("s");
        assert!(!store.join("CLEAN").exists(), "round {round}");
        assert!(MetallManager::open(&store).is_err(), "round {round}: dirty store refused");
        // the synced store has segmented management on disk
        assert!(
            !metall_rs::alloc::mgmt_io::list_manifest_epochs(&store).unwrap().is_empty(),
            "round {round}: at least one manifest was committed before the kill"
        );
        {
            let m = MetallManager::open_unclean(&store)
                .expect("open_unclean recovers from the last complete manifest");
            assert!(
                m.doctor().unwrap().is_empty(),
                "round {round}: recovered store is structurally consistent"
            );
            // the recovered allocator is fully functional
            let off = m.allocate(64).unwrap();
            m.write::<u64>(off, 0xFEED);
            assert_eq!(m.read::<u64>(off), 0xFEED);
            m.deallocate(off).unwrap();
            m.construct::<u64>("post_recovery", round as u64).unwrap();
            m.close().unwrap();
        }
        let m = MetallManager::open(&store).expect("re-sealed store opens");
        assert_eq!(
            m.read::<u64>(m.find::<u64>("post_recovery").unwrap().unwrap()),
            round as u64
        );
        m.close().unwrap();
        assert_snapshot_intact(&d.join("snap"));
    }
}

/// Kill-9 under **watermark-driven background sync**: the child never
/// calls `sync()` in its churn loop — a tiny dirty-byte watermark plus an
/// interval timer keep the background flusher committing epochs under
/// continuous ingest, and a timer SIGKILL lands around flushes no
/// mutation-path caller requested. The recovery contract is the same as
/// for a torn foreground sync (the background engine writes through the
/// identical section/manifest protocol, so the torn-sync matrix above
/// covers its file surgeries too):
///
/// - plain `open()` refuses the dirty store,
/// - background flushes really committed manifests before the kill,
/// - `open_unclean()` recovers the last complete manifest, doctor-clean
///   and fully usable, and re-sealing works,
/// - the pre-churn snapshot is intact.
#[test]
fn kill9_mid_background_flush_recovers_from_last_complete_manifest() {
    use std::os::unix::process::ExitStatusExt;
    let mut rng = Xoshiro256ss::new(0xB65C);
    // the snapshot's own sync commits epoch 1 in the store; only epochs
    // past it prove the *background* triggers actually flushed
    let mut saw_background_epoch = false;
    for round in 0..3 {
        let d = TempDir::new(&format!("crash-bgsync-{round}"));
        let kill_at = 3 + rng.gen_range(200);
        let status = spawn_child("crash-bgsync", d.path(), kill_at);
        assert_eq!(
            status.signal(),
            Some(libc::SIGKILL),
            "round {round}: child must die by SIGKILL, got {status:?}"
        );
        let store = d.join("s");
        assert!(!store.join("CLEAN").exists(), "round {round}");
        assert!(MetallManager::open(&store).is_err(), "round {round}: dirty store refused");
        // the snapshot's sync committed epoch 1; the watermark flusher
        // kept committing after it without any sync() caller
        let epochs = metall_rs::alloc::mgmt_io::list_manifest_epochs(&store).unwrap();
        assert!(!epochs.is_empty(), "round {round}: at least one epoch before the kill");
        if epochs.iter().any(|&e| e > 1) {
            saw_background_epoch = true;
        }
        {
            let m = MetallManager::open_unclean(&store)
                .expect("open_unclean recovers from the last complete background epoch");
            assert!(
                m.doctor().unwrap().is_empty(),
                "round {round}: recovered store is structurally consistent"
            );
            let off = m.allocate(64).unwrap();
            m.write::<u64>(off, 0xB6);
            assert_eq!(m.read::<u64>(off), 0xB6);
            m.deallocate(off).unwrap();
            m.construct::<u64>("post_bg_recovery", round as u64).unwrap();
            m.close().unwrap();
        }
        let m = MetallManager::open(&store).expect("re-sealed store opens");
        assert_eq!(
            m.read::<u64>(m.find::<u64>("post_bg_recovery").unwrap().unwrap()),
            round as u64
        );
        m.close().unwrap();
        assert_snapshot_intact(&d.join("snap"));
    }
    // at least one of the three rounds must have lived long enough for a
    // watermark/interval-driven epoch to commit — otherwise this test
    // silently degrades into a plain recovery test
    assert!(
        saw_background_epoch,
        "no round committed a background epoch (epoch > 1) before its kill"
    );
}

/// Kill-9 under the **epoch-pipelined engine on a slow backend**: the
/// child runs depth-2 pipelining against partly-slept simulated lustre
/// and fires `sync_async` every other op, so at the kill instant epoch
/// N's commit (section writes, manifest rename) is typically in flight
/// with epoch N+1's sections already serialized in the queue. The
/// recovery contract does not change:
///
/// - plain `open()` refuses the dirty store,
/// - committed manifest epochs are strictly monotone on disk (the
///   commit-order invariant survives the crash),
/// - `open_unclean()` recovers on the **newest complete** manifest —
///   doctor-clean, fully usable — and re-sealing works,
/// - the pre-churn snapshot is intact.
#[test]
fn kill9_mid_pipelined_flush_recovers_on_newest_complete_manifest() {
    use std::os::unix::process::ExitStatusExt;
    let mut rng = Xoshiro256ss::new(0x919E);
    // the snapshot's own sync commits epoch 1; epochs past it prove the
    // pipelined engine really committed under churn before the kill
    let mut saw_pipelined_epoch = false;
    for round in 0..3 {
        let d = TempDir::new(&format!("crash-pipe-{round}"));
        let kill_at = 3 + rng.gen_range(200);
        let status = spawn_child("crash-pipeline", d.path(), kill_at);
        assert_eq!(
            status.signal(),
            Some(libc::SIGKILL),
            "round {round}: child must die by SIGKILL, got {status:?}"
        );
        let store = d.join("s");
        assert!(!store.join("CLEAN").exists(), "round {round}");
        assert!(MetallManager::open(&store).is_err(), "round {round}: dirty store refused");
        let epochs = metall_rs::alloc::mgmt_io::list_manifest_epochs(&store).unwrap();
        assert!(!epochs.is_empty(), "round {round}: at least one epoch before the kill");
        assert!(
            epochs.windows(2).all(|w| w[0] < w[1]),
            "round {round}: committed epochs strictly monotone: {epochs:?}"
        );
        if epochs.iter().any(|&e| e > 1) {
            saw_pipelined_epoch = true;
        }
        {
            let m = MetallManager::open_unclean(&store)
                .expect("open_unclean recovers on the newest complete manifest");
            assert!(
                m.doctor().unwrap().is_empty(),
                "round {round}: recovered store is structurally consistent"
            );
            let off = m.allocate(64).unwrap();
            m.write::<u64>(off, 0x919E);
            assert_eq!(m.read::<u64>(off), 0x919E);
            m.deallocate(off).unwrap();
            m.construct::<u64>("post_pipe_recovery", round as u64).unwrap();
            m.close().unwrap();
        }
        let m = MetallManager::open(&store).expect("re-sealed store opens");
        assert_eq!(
            m.read::<u64>(m.find::<u64>("post_pipe_recovery").unwrap().unwrap()),
            round as u64
        );
        m.close().unwrap();
        assert_snapshot_intact(&d.join("snap"));
    }
    assert!(
        saw_pipelined_epoch,
        "no round committed a pipelined epoch (epoch > 1) before its kill"
    );
}

/// Deterministic torn-sync matrix: truncate (and separately delete) each
/// file the *newest* sync wrote — every rewritten section and the
/// manifest itself — and assert recovery lands exactly on the previous
/// complete manifest's state. This is the file-surgery twin of the
/// kill-9 test above: a crash inside sync N can only tear files sync N
/// was writing, because committed sections are immutable and GC never
/// touches anything manifests N-1 or N reference.
#[test]
fn torn_sync_truncation_matrix_recovers_previous_epoch() {
    use metall_rs::alloc::mgmt_io;

    fn copy_tree(src: &Path, dst: &Path) {
        std::fs::create_dir_all(dst).unwrap();
        for e in std::fs::read_dir(src).unwrap().flatten() {
            let p = e.path();
            let t = dst.join(e.file_name());
            if p.is_dir() {
                copy_tree(&p, &t);
            } else {
                std::fs::copy(&p, &t).unwrap();
            }
        }
    }

    let d = TempDir::new("torn-matrix");
    let store = d.join("s");
    {
        let m = MetallManager::create_with(&store, ManagerOptions::small_for_tests()).unwrap();
        m.construct::<u64>("a", 1).unwrap();
        m.sync().unwrap(); // epoch 1: complete, holds "a"
        m.construct::<u64>("b", 2).unwrap();
        m.sync().unwrap(); // epoch 2: holds "a" and "b"
        std::mem::forget(m); // crash without close
    }
    assert_eq!(mgmt_io::list_manifest_epochs(&store).unwrap(), vec![1, 2]);
    let man2 = mgmt_io::read_manifest(&store, 2).unwrap();
    // every file sync #2 wrote: its manifest + the sections it re-serialized
    let mut epoch2_files = vec![mgmt_io::manifest_file_name(2)];
    epoch2_files.extend(
        man2.sections
            .iter()
            .filter(|r| r.file.contains("000000000002"))
            .map(|r| r.file.clone()),
    );
    assert!(
        epoch2_files.len() >= 3,
        "sync #2 rewrote the manifest plus ≥2 sections: {epoch2_files:?}"
    );
    for (i, file) in epoch2_files.iter().enumerate() {
        for surgery in ["truncate", "delete"] {
            let variant = d.join(format!("v{i}-{surgery}"));
            copy_tree(&store, &variant);
            let victim = variant.join(file);
            match surgery {
                "truncate" => {
                    let bytes = std::fs::read(&victim).unwrap();
                    std::fs::write(&victim, &bytes[..bytes.len() / 2]).unwrap();
                }
                _ => std::fs::remove_file(&victim).unwrap(),
            }
            let m = MetallManager::open_unclean(&variant).unwrap_or_else(|e| {
                panic!("{surgery} {file}: recovery from the previous manifest failed: {e}")
            });
            assert!(
                m.find::<u64>("a").unwrap().is_some(),
                "{surgery} {file}: epoch-1 state present"
            );
            assert!(
                m.find::<u64>("b").unwrap().is_none(),
                "{surgery} {file}: torn epoch-2 state rolled back"
            );
            assert!(m.doctor().unwrap().is_empty(), "{surgery} {file}");
            m.close().unwrap();
        }
    }
    // the untouched store recovers the full epoch-2 state
    let m = MetallManager::open_unclean(&store).unwrap();
    assert_eq!(m.read::<u64>(m.find::<u64>("b").unwrap().unwrap()), 2);
    m.close().unwrap();
}

/// Torn-**queue** matrix, the pipelined twin of the test above: with the
/// depth-2 engine two epochs can have files on disk at the same time, so
/// the surgery set is every file the two newest epochs wrote — both
/// manifests plus every section tagged with either epoch. Recovery must
/// land on the **newest manifest that remains complete**: tearing an
/// epoch-3 file (or manifest 3 itself) rolls back to epoch 2; tearing a
/// file only epoch 2's manifest references (manifest 2 itself, or a
/// section epoch 3 superseded) leaves epoch 3 intact and recovery keeps
/// its full state. Sections referenced by *both* kept manifests are
/// excluded: they were committed before either in-flight epoch and are
/// immutable, so no crash inside the pipeline window can tear them.
#[test]
fn torn_pipeline_queue_matrix_recovers_newest_complete_manifest() {
    use metall_rs::alloc::mgmt_io;
    use std::collections::HashSet;

    fn copy_tree(src: &Path, dst: &Path) {
        std::fs::create_dir_all(dst).unwrap();
        for e in std::fs::read_dir(src).unwrap().flatten() {
            let p = e.path();
            let t = dst.join(e.file_name());
            if p.is_dir() {
                copy_tree(&p, &t);
            } else {
                std::fs::copy(&p, &t).unwrap();
            }
        }
    }

    let d = TempDir::new("torn-queue");
    let store = d.join("s");
    {
        let mut o = ManagerOptions::small_for_tests();
        o.sync_pipeline_depth = 2;
        let m = MetallManager::create_with(&store, o).unwrap();
        m.construct::<u64>("a", 1).unwrap();
        m.sync().unwrap(); // epoch 1: "a"
        m.construct::<u64>("b", 2).unwrap();
        m.sync().unwrap(); // epoch 2: "a", "b"
        m.construct::<u64>("c", 3).unwrap();
        m.sync().unwrap(); // epoch 3: "a", "b", "c"
        std::mem::forget(m); // crash without close
    }
    // GC keeps the newest manifest plus its fallback
    assert_eq!(mgmt_io::list_manifest_epochs(&store).unwrap(), vec![2, 3]);
    let man2 = mgmt_io::read_manifest(&store, 2).unwrap();
    let man3 = mgmt_io::read_manifest(&store, 3).unwrap();
    let closure = |m: &metall_rs::alloc::mgmt_io::Manifest, e: u64| -> HashSet<String> {
        let mut s: HashSet<String> = m.sections.iter().map(|r| r.file.clone()).collect();
        s.insert(mgmt_io::manifest_file_name(e));
        s
    };
    let refs2 = closure(&man2, 2);
    let refs3 = closure(&man3, 3);
    // every file the two newest epochs wrote, by its epoch tag
    let victims: Vec<&String> = refs2
        .union(&refs3)
        .filter(|f| f.contains("000000000002") || f.contains("000000000003"))
        .collect();
    let (mut rolled_back, mut kept_newest) = (0u32, 0u32);
    for (i, file) in victims.iter().enumerate() {
        let breaks3 = refs3.contains(*file);
        let breaks2 = refs2.contains(*file);
        if breaks3 && breaks2 {
            continue; // pre-pipeline immutable section: not a queue casualty
        }
        let expected_epoch = if breaks3 { 2u64 } else { 3 };
        for surgery in ["truncate", "delete"] {
            let variant = d.join(format!("q{i}-{surgery}"));
            copy_tree(&store, &variant);
            let victim = variant.join(file);
            match surgery {
                "truncate" => {
                    let bytes = std::fs::read(&victim).unwrap();
                    std::fs::write(&victim, &bytes[..bytes.len() / 2]).unwrap();
                }
                _ => std::fs::remove_file(&victim).unwrap(),
            }
            let m = MetallManager::open_unclean(&variant).unwrap_or_else(|e| {
                panic!("{surgery} {file}: recovery on the newest complete manifest failed: {e}")
            });
            assert!(m.find::<u64>("a").unwrap().is_some(), "{surgery} {file}");
            assert!(m.find::<u64>("b").unwrap().is_some(), "{surgery} {file}");
            if expected_epoch == 3 {
                assert_eq!(
                    m.read::<u64>(m.find::<u64>("c").unwrap().expect("epoch-3 state intact")),
                    3,
                    "{surgery} {file}"
                );
            } else {
                assert!(
                    m.find::<u64>("c").unwrap().is_none(),
                    "{surgery} {file}: torn epoch-3 state rolled back"
                );
            }
            assert!(m.doctor().unwrap().is_empty(), "{surgery} {file}");
            m.close().unwrap();
        }
        if expected_epoch == 2 {
            rolled_back += 1;
        } else {
            kept_newest += 1;
        }
    }
    // the matrix must exercise both directions: epoch-3 casualties roll
    // back to 2, epoch-2-only casualties keep the newest epoch intact
    assert!(rolled_back >= 2, "≥2 epoch-3 files torn: {victims:?}");
    assert!(kept_newest >= 1, "≥1 epoch-2-only file torn: {victims:?}");
}

// ------------------------------------------------------------------------
// Container crash consistency (the per-operation commit log).

/// Elements the container-churn trace pushes into its `PVec`.
fn container_vec_value(i: u64) -> u64 {
    i.wrapping_mul(11).wrapping_add(3)
}

/// Values the container-churn trace maps key `k` to.
fn container_map_value(k: u64) -> u64 {
    k.wrapping_mul(3).wrapping_add(1)
}

/// Pushes/inserts committed before the deterministic kill-point children
/// arm `METALL_KILL_POINT` (enough to leave the vec at cap 64 and the
/// map several grows past its initial table).
const KPOINT_BASE: u64 = 50;

/// "crash-container" child: one `PVec`, one `PHashMapU64` and one
/// `BankedAdjacency` mutate in lock-step — push `op`, insert key `op`,
/// link edge `(op % 64) → op` — under the watermark-driven background
/// flusher, until a timer SIGKILL lands at an arbitrary instant. Every
/// op routes through the op log, so the parent can assert an exact
/// committed-prefix oracle over all three containers.
fn crash_container_child(dir: &Path, kill_at: u64) {
    let store = dir.join("s");
    let mut opts = ManagerOptions::small_for_tests();
    opts.sync_watermark_bytes = opts.chunk_size;
    opts.sync_interval_ms = 5;
    let m = MetallManager::create_with(&store, opts).unwrap();
    let v = PVec::<u64>::create(&m).unwrap();
    m.construct::<u64>("cv", v.offset()).unwrap();
    let map = PHashMapU64::<u64>::create(&m).unwrap();
    m.construct::<u64>("cm", map.offset()).unwrap();
    let g = BankedAdjacency::create(&m, 4).unwrap();
    m.construct::<u64>("cg", g.offset()).unwrap();
    m.sync().unwrap(); // epoch 1: the empty containers are durable
    let delay = std::time::Duration::from_millis(4 + kill_at % 60);
    std::thread::spawn(move || {
        std::thread::sleep(delay);
        unsafe { libc::raise(libc::SIGKILL) };
    });
    for op in 0u64.. {
        v.push(&m, container_vec_value(op)).unwrap();
        map.insert(&m, op, container_map_value(op)).unwrap();
        g.insert_edge(&m, op % 64, op).unwrap();
    }
    unreachable!("the timer SIGKILL is the only exit");
}

/// "kpoint-vec"/"kpoint-map" child: commit a base batch (epoch-synced),
/// then arm the named `METALL_KILL_POINT` and keep mutating — the next
/// capacity grow dies *between* publishing the new header and retiring
/// the old extent, the exact window the pre-fix code left dangling.
fn kill_point_child(dir: &Path, mode: &str) {
    let store = dir.join("s");
    let m = MetallManager::create_with(&store, ManagerOptions::small_for_tests()).unwrap();
    match mode {
        "kpoint-vec" => {
            let v = PVec::<u64>::create(&m).unwrap();
            m.construct::<u64>("cv", v.offset()).unwrap();
            for i in 0..KPOINT_BASE {
                v.push(&m, container_vec_value(i)).unwrap();
            }
            m.sync().unwrap();
            std::env::set_var("METALL_KILL_POINT", "pvec_grow_retire");
            for i in KPOINT_BASE.. {
                v.push(&m, container_vec_value(i)).unwrap();
            }
        }
        "kpoint-map" => {
            let map = PHashMapU64::<u64>::create(&m).unwrap();
            m.construct::<u64>("cm", map.offset()).unwrap();
            for k in 0..KPOINT_BASE {
                map.insert(&m, k, container_map_value(k)).unwrap();
            }
            m.sync().unwrap();
            std::env::set_var("METALL_KILL_POINT", "pmap_grow_retire");
            for k in KPOINT_BASE.. {
                map.insert(&m, k, container_map_value(k)).unwrap();
            }
        }
        other => panic!("unknown kill-point child mode {other}"),
    }
    unreachable!("the armed grow kill point must fire");
}

/// Kill-9 mid **container churn**: the op-log recovery contract. After
/// `open_unclean` replays the log tail, the three containers must hold
/// an exact *committed prefix* of the child's lock-step trace:
///
/// - the vec is `container_vec_value(0..lv)` exactly — no torn length,
///   no dangling `data_off`, no lost committed push,
/// - the map holds keys `0..lm` exactly (each with its oracle value,
///   the next key absent — a half-keyed slot must have been rolled
///   back), with `lm ∈ {lv-1, lv}` since the insert trails the push by
///   at most one op,
/// - the adjacency holds edges `(i % 64) → i` for `i < le` exactly,
///   `nedges` matching the materialized edge count (the two-header
///   `OP_EDGE` publish keeps counter and lists atomic),
/// - `doctor` — which runs `validate_containers` — reports nothing,
/// - the recovered containers keep working and re-seal cleanly.
#[test]
fn kill9_mid_container_churn_recovers_committed_prefix() {
    use std::os::unix::process::ExitStatusExt;
    let mut rng = Xoshiro256ss::new(0xC07A);
    // at least one round must actually exercise replay/adoption —
    // otherwise every kill landed on an epoch boundary and the test
    // silently degraded into plain manifest recovery
    let mut saw_replay = false;
    for round in 0..4 {
        let d = TempDir::new(&format!("crash-cont-{round}"));
        let kill_at = rng.gen_range(200);
        let status = spawn_child("crash-container", d.path(), kill_at);
        assert_eq!(
            status.signal(),
            Some(libc::SIGKILL),
            "round {round}: child must die by SIGKILL, got {status:?}"
        );
        let store = d.join("s");
        assert!(!store.join("CLEAN").exists(), "round {round}");
        assert!(MetallManager::open(&store).is_err(), "round {round}: dirty store refused");
        let (lv, lm) = {
            let m = MetallManager::open_unclean(&store)
                .expect("open_unclean replays the container op log");
            assert!(
                m.doctor().unwrap().is_empty(),
                "round {round}: container invariants hold after replay"
            );
            let st = m.oplog_stats();
            saw_replay |= st.recovered_adopted + st.recovered_forward + st.recovered_rollback > 0;
            assert_eq!(st.recovery_anomalies, 0, "round {round}: no unexplained header bytes");

            let v = PVec::<u64>::from_offset(m.read(m.find::<u64>("cv").unwrap().unwrap()));
            let map =
                PHashMapU64::<u64>::from_offset(m.read(m.find::<u64>("cm").unwrap().unwrap()));
            let g = BankedAdjacency::open(&m, m.read(m.find::<u64>("cg").unwrap().unwrap()));

            let lv = v.len(&m) as u64;
            for i in 0..lv {
                assert_eq!(v.get(&m, i as usize), container_vec_value(i), "round {round} vec[{i}]");
            }
            let lm = map.len(&m) as u64;
            assert!(
                lm <= lv && lv <= lm + 1,
                "round {round}: map len {lm} must trail vec len {lv} by at most one op"
            );
            for k in 0..lm {
                assert_eq!(map.get(&m, k), Some(container_map_value(k)), "round {round} map[{k}]");
            }
            assert_eq!(map.get(&m, lm), None, "round {round}: uncommitted key rolled back");
            let le = g.num_edges(&m);
            assert!(
                le <= lm && lm <= le + 1,
                "round {round}: edge count {le} must trail map len {lm} by at most one op"
            );
            let mut edges = g.to_edge_list(&m);
            assert_eq!(edges.len() as u64, le, "round {round}: nedges matches materialized edges");
            edges.sort_by_key(|&(_, dst)| dst);
            for (i, &(src, dst)) in edges.iter().enumerate() {
                assert_eq!(dst, i as u64, "round {round}: edges are the exact trace prefix");
                assert_eq!(src, dst % 64, "round {round}: edge {dst} hangs off its trace source");
            }
            // the recovered containers keep working: continue the trace
            v.push(&m, container_vec_value(lv)).unwrap();
            map.insert(&m, lm, container_map_value(lm)).unwrap();
            m.close().unwrap();
            (lv, lm)
        };
        let m = MetallManager::open(&store).expect("re-sealed store opens");
        let v = PVec::<u64>::from_offset(m.read(m.find::<u64>("cv").unwrap().unwrap()));
        assert_eq!(v.len(&m) as u64, lv + 1, "round {round}: post-recovery push persisted");
        assert_eq!(v.get(&m, lv as usize), container_vec_value(lv));
        let map = PHashMapU64::<u64>::from_offset(m.read(m.find::<u64>("cm").unwrap().unwrap()));
        assert_eq!(map.get(&m, lm), Some(container_map_value(lm)));
        assert!(m.doctor().unwrap().is_empty(), "round {round}: clean reopen audits clean");
        m.close().unwrap();
    }
    assert!(
        saw_replay,
        "no round left op-log records to replay/adopt — every kill landed on an epoch cut"
    );
}

/// Deterministic regression for the `PVec::grow` crash window: the child
/// dies *between* publishing the grown header and retiring the old
/// extent (`pvec_grow_retire`). The unsealed grow record's new image
/// already matches the header, so recovery must roll it **forward** —
/// adopt the new extent, release the retired one — leaving every
/// committed push intact. Under the pre-fix op order (deallocate before
/// publish) this exact kill left `data_off` dangling.
#[test]
fn kill_point_in_pvec_grow_retire_window_rolls_forward() {
    use std::os::unix::process::ExitStatusExt;
    let d = TempDir::new("kpoint-vec");
    let status = spawn_child("kpoint-vec", d.path(), 0);
    assert_eq!(status.signal(), Some(libc::SIGKILL), "armed kill point fires: {status:?}");
    let store = d.join("s");
    assert!(MetallManager::open(&store).is_err(), "dirty store refused");
    let m = MetallManager::open_unclean(&store).unwrap();
    assert!(m.doctor().unwrap().is_empty(), "recovered store audits clean");
    let st = m.oplog_stats();
    assert!(st.recovered_forward >= 1, "published-but-unsealed grow rolls forward: {st:?}");
    assert!(st.recovered_released >= 1, "the forward-rolled grow releases its retired extent");
    let v = PVec::<u64>::from_offset(m.read(m.find::<u64>("cv").unwrap().unwrap()));
    // cap doubles at pushes 5/9/17/33/65 — the armed kill fires inside
    // push 65's grow, after 64 committed pushes; push 65 itself never
    // logged an intent
    assert_eq!(v.len(&m), 64, "every committed push survives the mid-grow kill");
    for i in 0..64u64 {
        assert_eq!(v.get(&m, i as usize), container_vec_value(i), "vec[{i}]");
    }
    // the adopted extent is real: the vector keeps growing through it
    for i in 64..200u64 {
        v.push(&m, container_vec_value(i)).unwrap();
    }
    assert_eq!(v.len(&m), 200);
    m.close().unwrap();
    MetallManager::open(&store).expect("re-sealed store opens").close().unwrap();
}

/// Deterministic regression for the `PHashMap::grow` crash window
/// (`pmap_grow_retire`): same shape as the vec test — the rehashed
/// table is published, the commit seal never lands, the old table is
/// never freed. Recovery rolls the grow forward; every committed insert
/// must probe correctly through the adopted table.
#[test]
fn kill_point_in_pmap_grow_retire_window_rolls_forward() {
    use std::os::unix::process::ExitStatusExt;
    let d = TempDir::new("kpoint-map");
    let status = spawn_child("kpoint-map", d.path(), 0);
    assert_eq!(status.signal(), Some(libc::SIGKILL), "armed kill point fires: {status:?}");
    let store = d.join("s");
    assert!(MetallManager::open(&store).is_err(), "dirty store refused");
    let m = MetallManager::open_unclean(&store).unwrap();
    assert!(m.doctor().unwrap().is_empty(), "recovered store audits clean");
    let st = m.oplog_stats();
    assert!(st.recovered_forward >= 1, "published-but-unsealed grow rolls forward: {st:?}");
    assert!(st.recovered_released >= 1, "the forward-rolled grow releases the old table");
    let map = PHashMapU64::<u64>::from_offset(m.read(m.find::<u64>("cm").unwrap().unwrap()));
    let lm = map.len(&m) as u64;
    assert!(lm >= KPOINT_BASE, "the synced base batch survives, len {lm}");
    for k in 0..lm {
        assert_eq!(map.get(&m, k), Some(container_map_value(k)), "map[{k}]");
    }
    assert_eq!(map.get(&m, lm), None, "the grow-triggering insert never logged an intent");
    // the adopted table is real: inserts keep landing in it
    for k in lm..lm + 100 {
        map.insert(&m, k, container_map_value(k)).unwrap();
    }
    assert_eq!(map.len(&m) as u64, lm + 100);
    m.close().unwrap();
    MetallManager::open(&store).expect("re-sealed store opens").close().unwrap();
}

/// Kill while a large multi-chunk write is in flight: the CLEAN protocol
/// must still hold (this exercises the segment-extension path, not just
/// small-object churn).
#[test]
fn kill9_mid_large_write_still_refused() {
    use std::os::unix::process::ExitStatusExt;
    let d = TempDir::new("crash-large");
    // kill_at 0: the child dies before any post-snapshot op, i.e. with
    // the snapshot's sync as the last consistency point
    let status = spawn_child("crash", d.path(), 0);
    assert_eq!(status.signal(), Some(libc::SIGKILL));
    assert!(MetallManager::open(d.join("s")).is_err());
    assert_snapshot_intact(&d.join("snap"));
}

//! Live multi-process reader-attach integration tests (epoch snapshot
//! isolation).
//!
//! The same re-exec harness as `it_crash.rs`: a child process — this
//! test binary filtered down to `attach_child_entry` plus control env
//! vars — attaches a [`ReaderManager`] to a store the parent holds open
//! and keeps mutating. The parent asserts the attach contract:
//!
//! - a second **writer** is refused while an owner (or RO opener) holds
//!   the store lock, in-process and cross-process alike,
//! - an attached reader observes exactly its pinned committed epoch, no
//!   matter how the owner mutates afterward; `refresh()` advances it,
//! - epoch GC never collects a pinned manifest (or its sections) while
//!   the lease is live, and collects it again once the pin is gone,
//! - a `kill -9`'d reader's lease is reaped by the next registry scan
//!   (liveness = flock probe; the kernel dropped the dead fd's lock).

use std::path::{Path, PathBuf};
use std::process::Command;
use std::time::{Duration, Instant};

use metall_rs::alloc::mgmt_io::{list_manifest_epochs, manifest_file_name, read_manifest};
use metall_rs::alloc::readers::scan_pins;
use metall_rs::alloc::{ManagerOptions, MetallManager, ReaderManager, SegmentAlloc};
use metall_rs::containers::PVec;
use metall_rs::error::Error;
use metall_rs::util::tmp::TempDir;

const MODE_ENV: &str = "METALL_IT_ATTACH_MODE";
const DIR_ENV: &str = "METALL_IT_ATTACH_DIR";
const MARKER_ENV: &str = "METALL_IT_ATTACH_MARKER";

/// Records the owner pushes (quiesced) before the first commit.
const BASE_RECORDS: u64 = 200;

fn record_value(i: u64) -> u64 {
    i.wrapping_mul(7).wrapping_add(1)
}

/// Child-process body. A no-op without the control env vars.
#[test]
fn attach_child_entry() {
    let mode = match std::env::var(MODE_ENV) {
        Ok(m) => m,
        Err(_) => return, // normal test run: nothing to do
    };
    let dir = PathBuf::from(std::env::var(DIR_ENV).expect("child needs dir"));
    let store = dir.join("s");
    match mode.as_str() {
        // the owner (parent) is live and holds the exclusive store
        // lock; every writer-side open from another process must bounce
        "second-open" => {
            let err = MetallManager::open_unclean(&store)
                .err()
                .expect("second RW open of a live store must be refused");
            assert!(format!("{err}").contains("locked"), "{err}");
            let err = MetallManager::open(&store)
                .err()
                .expect("plain open of a live store must be refused");
            assert!(format!("{err}").contains("locked"), "{err}");
        }
        // attach, report readiness, then follow the owner's epochs and
        // check the committed-prefix contract on every advance
        "reader-verify" => reader_verify_child(&store),
        // attach, report readiness, then just sit holding the lease
        // until the parent SIGKILLs us
        "reader-hold" => {
            let r = ReaderManager::attach(&store).expect("attach to live store");
            touch_marker();
            assert!(r.epoch() > 0);
            loop {
                std::thread::sleep(Duration::from_millis(50));
            }
        }
        other => panic!("unknown child mode {other}"),
    }
}

fn touch_marker() {
    let marker = std::env::var(MARKER_ENV).expect("child needs marker path");
    std::fs::write(&marker, b"ready").expect("write ready marker");
}

/// The consistency discipline: a record visible in the view of
/// committed epoch E was written before E's flush finished, and the
/// (single-threaded) owner starts E+1's flush only after that — so on
/// every refresh, everything below the length observed at the
/// *previous* epoch's view must be bit-exact. The attach-time view is
/// seeded from live bytes while the owner is quiesced here, so its
/// whole length qualifies as the first stable prefix.
fn reader_verify_child(store: &Path) {
    let mut r = ReaderManager::attach(store).expect("attach to live store");
    let off = r
        .find::<u64>("log")
        .unwrap()
        .expect("'log' is named in the pinned epoch");
    let v = PVec::<u64>::from_offset(r.read(off));
    let len0 = v.len(&r);
    assert_eq!(len0, BASE_RECORDS as usize, "owner was quiesced at spawn");
    for i in 0..len0 {
        assert_eq!(v.get(&r, i), record_value(i as u64), "record {i} at attach");
    }
    // the attach is read-only end to end
    assert!(matches!(r.allocate(16), Err(Error::InvalidOp(_))));
    touch_marker(); // the owner starts mutating only after this

    let deadline = Instant::now() + Duration::from_secs(60);
    let mut advances = 0usize;
    let mut stable = len0; // verified-prefix bound for the NEXT view
    let mut prev_len = len0;
    while advances < 3 {
        assert!(Instant::now() < deadline, "owner kept committing; refresh must advance");
        if !r.refresh().expect("refresh") {
            std::thread::sleep(Duration::from_millis(5));
            continue;
        }
        advances += 1;
        let off = r.find::<u64>("log").unwrap().expect("'log' in every epoch");
        let v = PVec::<u64>::from_offset(r.read(off));
        let len = v.len(&r);
        assert!(len >= prev_len, "committed length is monotone: {len} < {prev_len}");
        for i in 0..stable {
            assert_eq!(v.get(&r, i), record_value(i as u64), "record {i} after advance {advances}");
        }
        stable = prev_len;
        prev_len = len;
    }
    // fall through: the harness exits 0, the lease Drop unlinks the file
}

/// Re-exec this test binary as the attach child.
fn spawn_child(mode: &str, dir: &Path, marker: &Path) -> std::process::Child {
    let exe = std::env::current_exe().expect("test binary path");
    Command::new(exe)
        .args(["attach_child_entry", "--exact", "--nocapture", "--test-threads=1"])
        .env(MODE_ENV, mode)
        .env(DIR_ENV, dir)
        .env(MARKER_ENV, marker)
        .spawn()
        .expect("spawn attach child")
}

fn wait_marker(marker: &Path) {
    let t0 = Instant::now();
    while !marker.exists() {
        assert!(t0.elapsed() < Duration::from_secs(30), "child never reported ready");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// One owner round: mutate management + data, then commit an epoch.
fn owner_round(m: &MetallManager, v: &PVec<u64>, next: &mut u64, round: usize) {
    for _ in 0..40 {
        v.push(m, record_value(*next)).unwrap();
        *next += 1;
    }
    m.construct::<u64>(&format!("r{round}"), round as u64).unwrap();
    m.sync().unwrap();
}

#[test]
fn double_rw_open_is_rejected_while_owner_live() {
    let d = TempDir::new("attach-lock");
    let store = d.join("s");
    let m = MetallManager::create_with(&store, ManagerOptions::small_for_tests()).unwrap();

    // in-process: flock is per open-file-description, so a second open
    // in the same process conflicts exactly like another process would
    for (what, res) in [
        ("open", MetallManager::open(&store).err()),
        ("open_unclean", MetallManager::open_unclean(&store).err()),
        ("open_read_only", MetallManager::open_read_only(&store).err()),
    ] {
        let err = res.unwrap_or_else(|| panic!("{what} of a live store must be refused"));
        assert!(format!("{err}").contains("locked"), "{what}: {err}");
    }

    // cross-process: the child asserts the same refusals from outside
    let marker = d.join("unused-marker");
    let mut child = spawn_child("second-open", d.path(), &marker);
    let status = child.wait().expect("wait for second-open child");
    assert!(status.success(), "second-open child failed: {status:?}");

    m.close().unwrap();

    // closed store: RO openers share the lock with each other but still
    // exclude writers
    let ro1 = MetallManager::open_read_only(&store).unwrap();
    let ro2 = MetallManager::open_read_only(&store).unwrap();
    let err = MetallManager::open(&store).err().expect("RW open must wait for RO holders");
    assert!(format!("{err}").contains("locked"), "{err}");
    drop(ro1);
    drop(ro2);
    MetallManager::open(&store).unwrap().close().unwrap();
}

#[test]
fn snapshot_isolation_pinned_view_survives_owner_mutation() {
    let d = TempDir::new("attach-iso");
    let store = d.join("s");
    let m = MetallManager::create_with(&store, ManagerOptions::small_for_tests()).unwrap();
    let v = PVec::<u64>::create(&m).unwrap();
    m.construct::<u64>("log", v.offset()).unwrap();
    for i in 0..500u64 {
        v.push(&m, record_value(i)).unwrap();
    }
    m.sync().unwrap();

    // attach pins the newest committed epoch with zero staleness
    let mut r = ReaderManager::attach(&store).unwrap();
    assert_eq!(r.epoch(), *list_manifest_epochs(&store).unwrap().last().unwrap());
    assert_eq!(r.attach_stats().staleness_epochs, 0);
    assert!(matches!(r.allocate(8), Err(Error::InvalidOp(_))));
    assert!(matches!(SegmentAlloc::deallocate(&r, 64), Err(Error::InvalidOp(_))));
    let off = r.find::<u64>("log").unwrap().unwrap();
    let rv = PVec::<u64>::from_offset(r.read(off));
    assert_eq!(rv.len(&r), 500);

    // the owner rewrites everything and grows the vector, then commits
    for i in 0..500u64 {
        v.set(&m, i as usize, 9999);
    }
    for i in 500..800u64 {
        v.push(&m, record_value(i)).unwrap();
    }
    m.construct::<u64>("v2", 2).unwrap();
    m.sync().unwrap();

    // the pinned view is frozen at its epoch…
    assert_eq!(rv.len(&r), 500, "pinned view must not see the growth");
    for i in 0..500u64 {
        assert_eq!(rv.get(&r, i as usize), record_value(i), "pinned record {i}");
    }
    assert!(r.find::<u64>("v2").unwrap().is_none(), "pinned names are frozen too");

    // …until refresh() re-pins to the new commit
    assert!(r.refresh().unwrap(), "a newer epoch exists");
    let off = r.find::<u64>("log").unwrap().unwrap();
    let rv = PVec::<u64>::from_offset(r.read(off));
    assert_eq!(rv.len(&r), 800);
    for i in 0..500 {
        assert_eq!(rv.get(&r, i), 9999, "refreshed record {i}");
    }
    for i in 500..800u64 {
        assert_eq!(rv.get(&r, i as usize), record_value(i), "refreshed record {i}");
    }
    assert!(r.find::<u64>("v2").unwrap().is_some());
    assert!(!r.refresh().unwrap(), "no newer epoch: refresh is a no-op");

    r.detach().unwrap();
    m.close().unwrap();
}

#[test]
fn gc_preserves_pinned_epoch_across_sync_cycles() {
    let d = TempDir::new("attach-gc");
    let store = d.join("s");
    let m = MetallManager::create_with(&store, ManagerOptions::small_for_tests()).unwrap();
    let v = PVec::<u64>::create(&m).unwrap();
    m.construct::<u64>("log", v.offset()).unwrap();
    let mut next = 0u64;
    owner_round(&m, &v, &mut next, 0);

    let pinned = *list_manifest_epochs(&store).unwrap().last().unwrap();
    let mut r = ReaderManager::attach(&store).unwrap();
    assert_eq!(r.epoch(), pinned);
    let pinned_sections = read_manifest(&store, pinned).unwrap().sections;

    // six epochs of churn: without the pin, `pinned` would be far
    // behind the keep window and collected — the lease must hold it
    for round in 1..=6 {
        owner_round(&m, &v, &mut next, round);
        assert!(
            store.join(manifest_file_name(pinned)).exists(),
            "round {round}: pinned manifest was GC'd with the lease live"
        );
        for s in &pinned_sections {
            assert!(store.join(&s.file).exists(), "round {round}: pinned section {}", s.file);
        }
        // the frozen view stays fully readable the whole time
        let off = r.find::<u64>("log").unwrap().unwrap();
        assert_eq!(PVec::<u64>::from_offset(r.read(off)).len(&r), 40);
    }

    // unpin (refresh to newest), let two more cycles run: the old epoch
    // is now collectable and must actually go away
    assert!(r.refresh().unwrap());
    assert!(r.epoch() > pinned);
    for round in 7..=8 {
        owner_round(&m, &v, &mut next, round);
    }
    assert!(
        !store.join(manifest_file_name(pinned)).exists(),
        "unpinned old manifest must be collected again"
    );

    r.detach().unwrap();
    m.close().unwrap();
}

#[test]
fn attach_requires_committed_epoch_and_works_on_closed_store() {
    let d = TempDir::new("attach-epoch");
    let store = d.join("s");
    let m = MetallManager::create_with(&store, ManagerOptions::small_for_tests()).unwrap();
    let v = PVec::<u64>::create(&m).unwrap();
    m.construct::<u64>("log", v.offset()).unwrap();
    v.push(&m, record_value(0)).unwrap();

    // never synced: nothing committed to pin
    let err = ReaderManager::attach(&store).err().expect("attach needs a committed epoch");
    assert!(format!("{err}").contains("no committed epoch"), "{err}");
    // and the failed attempt leaves no lease behind
    assert_eq!(scan_pins(&store).live, 0);

    m.sync().unwrap();
    let r = ReaderManager::attach(&store).unwrap();
    assert_eq!(PVec::<u64>::from_offset(r.read(r.find::<u64>("log").unwrap().unwrap())).len(&r), 1);
    r.detach().unwrap();
    m.close().unwrap();

    // a cleanly closed store attaches just as well (no owner needed)
    let r = ReaderManager::attach(&store).unwrap();
    let rv = PVec::<u64>::from_offset(r.read(r.find::<u64>("log").unwrap().unwrap()));
    assert_eq!(rv.get(&r, 0), record_value(0));
    r.detach().unwrap();
}

#[test]
fn reader_follows_live_owner_across_epochs() {
    let d = TempDir::new("attach-live");
    let store = d.join("s");
    let m = MetallManager::create_with(&store, ManagerOptions::small_for_tests()).unwrap();
    let v = PVec::<u64>::create(&m).unwrap();
    m.construct::<u64>("log", v.offset()).unwrap();
    let mut next = 0u64;
    for _ in 0..BASE_RECORDS {
        v.push(&m, record_value(next)).unwrap();
        next += 1;
    }
    m.sync().unwrap(); // the epoch the child pins, owner quiesced

    let marker = d.join("ready");
    let mut child = spawn_child("reader-verify", d.path(), &marker);
    wait_marker(&marker);

    // keep committing epochs until the child has verified three
    // advances of its view (it exits 0 on success, panics on any
    // consistency violation)
    let t0 = Instant::now();
    let mut round = 1usize;
    let status = loop {
        if let Some(status) = child.try_wait().expect("try_wait") {
            break status;
        }
        if t0.elapsed() > Duration::from_secs(90) {
            let _ = child.kill();
            panic!("reader-verify child did not finish");
        }
        owner_round(&m, &v, &mut next, round);
        round += 1;
        std::thread::sleep(Duration::from_millis(5));
    };
    assert!(status.success(), "reader-verify child failed: {status:?}");

    // clean exit dropped the lease
    let pins = scan_pins(&store);
    assert_eq!(pins.live, 0, "no live lease after the reader exited");
    m.close().unwrap();
}

#[test]
fn kill9_reader_lease_is_reaped_and_epoch_collectable_again() {
    use std::os::unix::process::ExitStatusExt;
    let d = TempDir::new("attach-kill9");
    let store = d.join("s");
    let m = MetallManager::create_with(&store, ManagerOptions::small_for_tests()).unwrap();
    let v = PVec::<u64>::create(&m).unwrap();
    m.construct::<u64>("log", v.offset()).unwrap();
    let mut next = 0u64;
    owner_round(&m, &v, &mut next, 0);
    let pinned = *list_manifest_epochs(&store).unwrap().last().unwrap();

    let marker = d.join("ready");
    let mut child = spawn_child("reader-hold", d.path(), &marker);
    wait_marker(&marker);

    // the lease is live and pins the attach epoch; GC honours it
    let pins = scan_pins(&store);
    assert_eq!(pins.live, 1);
    assert!(!pins.pin_all, "a settled reader pins one epoch, not everything");
    assert_eq!(pins.epochs, [pinned]);
    for round in 1..=2 {
        owner_round(&m, &v, &mut next, round);
    }
    assert!(store.join(manifest_file_name(pinned)).exists());

    // kill -9: no Drop runs, the lease file stays behind — but the
    // kernel releases the dead process's flock, so the next scan probes
    // the lease as acquirable, reaps it, and unpins the epoch
    child.kill().expect("SIGKILL the reader");
    let status = child.wait().expect("reap the reader");
    assert_eq!(status.signal(), Some(libc::SIGKILL));
    let pins = scan_pins(&store);
    assert_eq!(pins.live, 0, "dead reader must not count as live");
    assert_eq!(pins.reaped, 1, "stale lease must be reaped");

    for round in 3..=4 {
        owner_round(&m, &v, &mut next, round);
    }
    assert!(
        !store.join(manifest_file_name(pinned)).exists(),
        "after the reap, the old epoch is collectable again"
    );
    m.close().unwrap();
}

//! GBTL integration: persistent matrices + the five algorithms over the
//! SNAP stand-ins, cross-checked between the DRAM path, the persistent
//! path, and (where artifacts exist) the PJRT engine.

use metall_rs::alloc::{ManagerOptions, MetallManager};
use metall_rs::gbtl::algorithms::{bfs_level, ktruss, pagerank, sssp, triangle_count};
use metall_rs::gbtl::ops::{mxm, mxv, reduce_matrix, vxm};
use metall_rs::gbtl::semiring::{MinPlus, PlusTimes};
use metall_rs::gbtl::types::GrbVector;
use metall_rs::gbtl::{GrbMatrix, HeapAlloc};
use metall_rs::graph::datasets;
use metall_rs::graph::ell::EllGraph;
use metall_rs::runtime::engine::AnalyticsEngine;
use metall_rs::util::rng::Xoshiro256ss;
use metall_rs::util::tmp::TempDir;

#[test]
fn all_five_algorithms_run_on_persistent_matrix() {
    let d = TempDir::new("gbtl5");
    let ds = datasets::load("EE").unwrap(); // smallest (1005 vertices)
    let store = d.join("s");
    {
        let m = MetallManager::create_with(&store, ManagerOptions::small_for_tests())
            .unwrap();
        let mat = GrbMatrix::from_edges(&m, ds.n, &ds.edges).unwrap();
        m.construct::<GrbMatrix>("mat", mat).unwrap();
        m.close().unwrap();
    }
    let m = MetallManager::open_read_only(&store).unwrap();
    let mat: GrbMatrix = m.read(m.find::<GrbMatrix>("mat").unwrap().unwrap());

    let levels = bfs_level(&m, &mat, 0);
    assert_eq!(levels[0], 0);
    assert!(levels.iter().filter(|&&l| l >= 0).count() > 1);

    let (ranks, iters) = pagerank(&m, &mat, 0.85, 100, 1e-9);
    assert!(iters > 1);
    assert!((ranks.iter().sum::<f64>() - 1.0).abs() < 1e-6);

    let dist = sssp(&m, &mat, 0);
    for i in 0..ds.n {
        if levels[i] >= 0 {
            assert_eq!(dist[i], levels[i] as f64, "unweighted sssp == bfs, v{i}");
        } else {
            assert!(dist[i].is_infinite());
        }
    }

    let ntri = triangle_count(&m, &mat).unwrap();
    assert!(ntri > 0, "a power-law graph of this density has triangles");

    let t3 = ktruss(&m, &mat, 3).unwrap();
    assert!(!t3.is_empty());
    assert!(t3.len() <= mat.nvals(&m));
}

/// Property tests: random sparse matrices vs. a dense oracle, over two
/// semirings, for mxv / vxm / mxm (masked and unmasked).
#[test]
fn matrix_ops_match_dense_oracle_randomized() {
    let h = HeapAlloc::new().unwrap();
    let mut rng = Xoshiro256ss::new(2024);
    for case in 0..25 {
        let n = 4 + rng.gen_range(28) as usize;
        let density = 0.05 + rng.next_f64() * 0.4;
        let mut trips = Vec::new();
        let mut dense = vec![vec![0.0f64; n]; n];
        for r in 0..n {
            for c in 0..n {
                if rng.next_f64() < density {
                    let v = (rng.gen_range(9) + 1) as f64;
                    trips.push((r as u64, c as u64, v));
                    dense[r][c] = v;
                }
            }
        }
        let m = GrbMatrix::build(&h, n, n, &mut trips).unwrap();
        let u = GrbVector {
            vals: (0..n).map(|i| (i % 7) as f64 + 0.5).collect(),
            mask: vec![true; n],
        };

        // mxv over plus-times
        let w = mxv::<PlusTimes, _>(&h, &m, &u);
        for r in 0..n {
            let want: f64 = (0..n).map(|c| dense[r][c] * u.vals[c]).sum();
            let got = w.get(r).unwrap_or(0.0);
            assert!((got - want).abs() < 1e-9, "case {case} mxv row {r}");
        }

        // vxm == transpose-mxv
        let wv = vxm::<PlusTimes, _>(&h, &u, &m);
        for c in 0..n {
            let want: f64 = (0..n).map(|r| u.vals[r] * dense[r][c]).sum();
            assert!((wv.get(c).unwrap_or(0.0) - want).abs() < 1e-9, "case {case} vxm col {c}");
        }

        // mxv over min-plus (only where a row has structure)
        let wm = mxv::<MinPlus, _>(&h, &m, &u);
        for r in 0..n {
            let want = (0..n)
                .filter(|&c| dense[r][c] != 0.0)
                .map(|c| dense[r][c] + u.vals[c])
                .fold(f64::INFINITY, f64::min);
            if want.is_finite() {
                assert!((wm.get(r).unwrap() - want).abs() < 1e-9, "case {case} minplus {r}");
            } else {
                assert!(wm.get(r).is_none());
            }
        }

        // mxm vs dense matmul + total reduction
        let sq = mxm::<PlusTimes, _, _, _>(&h, &m, &h, &m, &h, None).unwrap();
        let dsq = sq.to_dense(&h);
        let mut want_total = 0.0;
        for r in 0..n {
            for c in 0..n {
                let want: f64 = (0..n).map(|k| dense[r][k] * dense[k][c]).sum();
                assert!((dsq[r][c] - want).abs() < 1e-6, "case {case} mxm [{r}][{c}]");
                want_total += want;
            }
        }
        let got_total = reduce_matrix::<PlusTimes, _>(&h, &sq);
        assert!((got_total - want_total).abs() / want_total.max(1.0) < 1e-9);
    }
}

#[test]
fn dram_and_persistent_paths_agree_on_all_datasets() {
    let d = TempDir::new("gbtlagree");
    for ds in datasets::all() {
        let h = HeapAlloc::new().unwrap();
        let dram = GrbMatrix::from_edges(&h, ds.n, &ds.edges).unwrap();
        let store = d.join(ds.name);
        let m = MetallManager::create_with(&store, ManagerOptions::small_for_tests())
            .unwrap();
        let pers = GrbMatrix::from_edges(&m, ds.n, &ds.edges).unwrap();

        assert_eq!(dram.nvals(&h), pers.nvals(&m), "{}", ds.name);
        assert_eq!(bfs_level(&h, &dram, 0), bfs_level(&m, &pers, 0), "{}", ds.name);
        let (ra, _) = pagerank(&h, &dram, 0.85, 30, 0.0);
        let (rb, _) = pagerank(&m, &pers, 0.85, 30, 0.0);
        for (x, y) in ra.iter().zip(&rb) {
            assert!((x - y).abs() < 1e-12, "{}", ds.name);
        }
        m.close().unwrap();
    }
}

/// Cross-stack agreement: GBTL (CSR/semiring) vs EllGraph native vs the
/// PJRT engine (Pallas kernels) on the same graph.
#[test]
fn three_implementations_agree() {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let ds = datasets::load("EE").unwrap();
    let h = HeapAlloc::new().unwrap();
    let mat = GrbMatrix::from_edges(&h, ds.n, &ds.edges).unwrap();
    // GrbMatrix::from_edges dedups; mirror that for the other paths
    let mut edges = ds.edges.clone();
    edges.sort_unstable();
    edges.dedup();
    let ell = EllGraph::from_edges(ds.n, &edges, 32);

    // 1 vs 2: gbtl vs native
    let (r_gbtl, _) = pagerank(&h, &mat, 0.85, 25, 0.0);
    let r_native = ell.pagerank_native(0.85, 25);
    for i in 0..ds.n {
        assert!(
            (r_gbtl[i] - r_native[i] as f64).abs() < 1e-4,
            "gbtl vs native at {i}: {} vs {}",
            r_gbtl[i],
            r_native[i]
        );
    }
    let l_gbtl = bfs_level(&h, &mat, 0);
    let l_native = ell.bfs_native(0);
    assert_eq!(l_gbtl, l_native);

    // 3: PJRT engine (skip silently without artifacts; Makefile builds them)
    if artifacts.join("manifest.txt").exists() {
        let eng = AnalyticsEngine::new(&artifacts).unwrap();
        if let Ok(run) = eng.pagerank(&ell, 25, 0.0) {
            for i in 0..ds.n {
                assert!(
                    (run.values[i] as f64 - r_gbtl[i]).abs() < 1e-4,
                    "pjrt vs gbtl at {i}"
                );
            }
        } else {
            eprintln!("skipping PJRT leg: no variant large enough");
        }
        let bfs_run = eng.bfs(&ell, 0).unwrap();
        for i in 0..ds.n {
            assert_eq!(bfs_run.values[i] as i64, l_gbtl[i], "pjrt bfs at {i}");
        }
    } else {
        eprintln!("skipping PJRT leg: run `make artifacts`");
    }
}

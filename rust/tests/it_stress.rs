//! Multi-thread stress: 8 threads hammering `allocate`/`deallocate`
//! through the lock-free fast path while also writing persistent
//! containers (`PVec`, `PHashMapU64`) on ONE shared manager, via the
//! `Send + Sync` [`MetallHandle`] API. Asserts post-join integrity, that
//! a close/open cycle round-trips every byte, and that full teardown
//! leaks nothing.

use metall_rs::alloc::{ManagerOptions, MetallHandle, MetallManager, SegmentAlloc};
use metall_rs::containers::{PHashMapU64, PVec};
use metall_rs::util::rng::Xoshiro256ss;
use metall_rs::util::tmp::TempDir;

const NTHREADS: u64 = 8;
const VEC_ITEMS: u64 = 400;
const MAP_ITEMS: u64 = 250;
const CHURN_OPS: usize = 2000;

fn opts() -> ManagerOptions {
    // explicitly sharded: 8 threads over 4 shards exercises the
    // cross-shard free routing under real scheduler placement
    let mut o = ManagerOptions::small_for_tests();
    o.shards = 4;
    o
}

fn vec_value(t: u64, i: u64) -> u64 {
    t * 1_000_000 + i
}

fn map_key(t: u64, i: u64) -> u64 {
    t * 1_000_000 + i
}

#[test]
fn eight_threads_alloc_churn_plus_container_writers() {
    let d = TempDir::new("stress8");
    let store = d.join("s");
    let h = MetallHandle::new(MetallManager::create_with(&store, opts()).unwrap());

    // every thread builds its own containers and churns the allocator;
    // the *allocator state* underneath is fully shared
    let results: Vec<(u64, u64, u64)> = std::thread::scope(|s| {
        (0..NTHREADS)
            .map(|t| {
                let h = h.clone();
                s.spawn(move || {
                    let v = PVec::<u64>::create(&h).unwrap();
                    let map = PHashMapU64::<u64>::create(&h).unwrap();
                    let mut rng = Xoshiro256ss::new(0xBEEF + t);
                    let mut scratch: Vec<(u64, u64)> = Vec::new(); // (offset, tag)
                    for i in 0..VEC_ITEMS.max(MAP_ITEMS) {
                        if i < VEC_ITEMS {
                            v.push(&h, vec_value(t, i)).unwrap();
                        }
                        if i < MAP_ITEMS {
                            assert!(map.insert(&h, map_key(t, i), map_key(t, i) * 3).unwrap());
                        }
                        // interleaved raw churn across mixed size classes
                        for _ in 0..CHURN_OPS / VEC_ITEMS as usize {
                            if scratch.len() >= 64 || (!scratch.is_empty() && rng.next_f64() < 0.45)
                            {
                                let j = rng.gen_range(scratch.len() as u64) as usize;
                                let (off, tag) = scratch.swap_remove(j);
                                assert_eq!(h.read::<u64>(off), tag, "thread {t}: tag corrupted");
                                h.deallocate(off).unwrap();
                            } else {
                                let size = 8usize << rng.gen_range(7); // 8..=512
                                let off = SegmentAlloc::allocate(&h, size).unwrap();
                                let tag = rng.next_u64();
                                h.write::<u64>(off, tag);
                                scratch.push((off, tag));
                            }
                        }
                    }
                    // leave the scratch allocations live on purpose: they
                    // must not disturb container data, and we free them
                    // post-join to test cross-thread deallocation
                    let scratch_vec = PVec::<u64>::create(&h).unwrap();
                    for &(off, _) in &scratch {
                        scratch_vec.push(&h, off).unwrap();
                    }
                    for &(off, tag) in &scratch {
                        assert_eq!(h.read::<u64>(off), tag, "thread {t}: post-churn tag");
                    }
                    (t, v.offset(), map.offset())
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|j| j.join().unwrap())
            .collect()
    });

    // post-join integrity on the live manager
    for &(t, voff, moff) in &results {
        let v = PVec::<u64>::from_offset(voff);
        assert_eq!(v.len(&*h), VEC_ITEMS as usize, "thread {t} vec length");
        for i in 0..VEC_ITEMS {
            assert_eq!(v.get(&*h, i as usize), vec_value(t, i), "thread {t} vec[{i}]");
        }
        let map = PHashMapU64::<u64>::from_offset(moff);
        assert_eq!(map.len(&*h), MAP_ITEMS as usize, "thread {t} map length");
        for i in 0..MAP_ITEMS {
            assert_eq!(
                map.get(&*h, map_key(t, i)),
                Some(map_key(t, i) * 3),
                "thread {t} map[{i}]"
            );
        }
        h.construct::<u64>(&format!("vec{t}"), voff).unwrap();
        h.construct::<u64>(&format!("map{t}"), moff).unwrap();
    }
    assert!(h.doctor().unwrap().is_empty(), "healthy after the stampede");
    let st = h.stats();
    assert!(st.fast_claims > 0, "the lock-free claim path was exercised");
    h.sync().unwrap(); // drains the remote-free queues (caches preserved)
    let ss = h.shard_stats();
    assert_eq!(ss.len(), 4);
    assert_eq!(
        st.fast_claims,
        ss.iter().map(|s| s.fast_claims).sum::<u64>(),
        "totals aggregate the per-shard counters"
    );
    assert_eq!(
        ss.iter().map(|s| s.remote_frees).sum::<u64>(),
        ss.iter().map(|s| s.remote_drained).sum::<u64>(),
        "every queued cross-shard free was drained: {ss:?}"
    );
    h.try_close().expect("all worker handles dropped at join");

    // close/open round-trip: every container byte survives
    let m = MetallManager::open(&store).unwrap();
    for t in 0..NTHREADS {
        let voff = m.read::<u64>(m.find::<u64>(&format!("vec{t}")).unwrap().unwrap());
        let v = PVec::<u64>::from_offset(voff);
        assert_eq!(v.len(&m), VEC_ITEMS as usize);
        for i in 0..VEC_ITEMS {
            assert_eq!(v.get(&m, i as usize), vec_value(t, i), "reattach vec{t}[{i}]");
        }
        let moff = m.read::<u64>(m.find::<u64>(&format!("map{t}")).unwrap().unwrap());
        let map = PHashMapU64::<u64>::from_offset(moff);
        for i in 0..MAP_ITEMS {
            assert_eq!(map.get(&m, map_key(t, i)), Some(map_key(t, i) * 3));
        }
    }
    assert!(m.doctor().unwrap().is_empty());
    m.close().unwrap();
}

/// Deterministic two-phase variant: phase 1 races 8 allocating threads,
/// phase 2 frees everything from the main thread and asserts zero chunk
/// leakage — the cross-thread free path (cache → spill → bitset → chunk
/// release) fully unwinds what the fast path claimed.
#[test]
fn cross_thread_free_unwinds_everything() {
    let d = TempDir::new("stress-unwind");
    let h = MetallHandle::new(MetallManager::create_with(d.join("s"), opts()).unwrap());
    let all: Vec<u64> = std::thread::scope(|s| {
        (0..NTHREADS)
            .map(|t| {
                let h = h.clone();
                s.spawn(move || {
                    let mut rng = Xoshiro256ss::new(77 + t);
                    (0..500)
                        .map(|_| {
                            let size = 8 + rng.gen_range(1000) as usize;
                            SegmentAlloc::allocate(&h, size).unwrap()
                        })
                        .collect::<Vec<u64>>()
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .flat_map(|j| j.join().unwrap())
            .collect()
    });
    // no duplicate offsets across threads
    let mut sorted = all.clone();
    sorted.sort_unstable();
    sorted.dedup();
    assert_eq!(sorted.len(), all.len(), "duplicate offsets handed out");
    for off in all {
        h.deallocate(off).unwrap();
    }
    // explicit drain: sync() alone preserves cache warmth by design
    h.flush_object_caches().unwrap();
    h.sync().unwrap();
    assert_eq!(h.used_segment_bytes(), 0, "every chunk returned to Free");
    h.try_close().unwrap();
}

//! Integration: AOT artifacts (HLO text) → PJRT runtime → results match
//! the pure-rust native oracles. Requires `make artifacts` (the Makefile
//! runs it before `cargo test`); tests skip gracefully if artifacts are
//! missing so bare `cargo test` still passes.

use metall_rs::graph::ell::EllGraph;
use metall_rs::graph::{bucket_hash32, rmat};
use metall_rs::runtime::engine::AnalyticsEngine;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts/ (run `make artifacts`)");
        None
    }
}

fn engine() -> Option<AnalyticsEngine> {
    artifacts_dir().map(|d| AnalyticsEngine::new(d).expect("engine"))
}

fn small_graph(seed: u64) -> EllGraph {
    // R-MAT scale 7: 128 vertices, ~512 edges
    let edges = rmat::RmatGenerator::graph500(7, 4).seed(seed).generate();
    EllGraph::from_edges(128, &edges, 32)
}

#[test]
fn pagerank_pjrt_matches_native() {
    let Some(eng) = engine() else { return };
    let g = small_graph(42);
    let run = eng.pagerank(&g, 50, 0.0).expect("pjrt pagerank");
    let native = g.pagerank_native(0.85, 50);
    assert_eq!(run.iterations, 50);
    assert_eq!(run.values.len(), g.n);
    let sum: f32 = run.values.iter().sum();
    assert!((sum - 1.0).abs() < 1e-3, "rank mass {sum}");
    for (i, (a, b)) in run.values.iter().zip(&native).enumerate() {
        assert!(
            (a - b).abs() < 1e-4,
            "vertex {i}: pjrt {a} vs native {b}"
        );
    }
}

#[test]
fn pagerank_early_stops_on_tolerance() {
    let Some(eng) = engine() else { return };
    let g = small_graph(7);
    let run = eng.pagerank(&g, 500, 1e-6).expect("pjrt pagerank");
    assert!(run.iterations < 500, "should converge well before 500 iters");
}

#[test]
fn bfs_pjrt_matches_native() {
    let Some(eng) = engine() else { return };
    let g = small_graph(1);
    let run = eng.bfs(&g, 0).expect("pjrt bfs");
    let native = g.bfs_native(0);
    assert_eq!(run.values.len(), g.n);
    for (i, (a, b)) in run.values.iter().zip(&native).enumerate() {
        assert_eq!(*a as i64, *b, "vertex {i} level mismatch");
    }
}

#[test]
fn bucket_pjrt_matches_native_hash() {
    let Some(eng) = engine() else { return };
    // 5000 ids: one compiled batch of 4096 + native tail of 904
    let src: Vec<u32> = (0..5000u32).map(|i| i.wrapping_mul(2_654_435_761)).collect();
    let got = eng.bucket(&src, 1024).expect("bucket");
    assert_eq!(got.len(), src.len());
    for (i, (&g_, &s)) in got.iter().zip(&src).enumerate() {
        assert_eq!(g_, bucket_hash32(s, 1024), "id {i}");
    }
}

#[test]
fn padding_to_larger_variant_is_exact() {
    let Some(eng) = engine() else { return };
    // a 40-vertex graph forced into the n=256 variant
    let edges: Vec<(u64, u64)> = (1..40u64).map(|s| (s, s / 2)).collect();
    let g = EllGraph::from_edges(40, &edges, 32);
    let run = eng.pagerank(&g, 30, 0.0).expect("pagerank");
    let native = g.pagerank_native(0.85, 30);
    for (a, b) in run.values.iter().zip(&native) {
        assert!((a - b).abs() < 1e-4, "pjrt {a} vs native {b}");
    }
    let sum: f32 = run.values.iter().sum();
    assert!((sum - 1.0).abs() < 1e-3);
}

//! Table 1 — memory device performance comparison. Prints the cost-model
//! constants the simulator derives from the paper's Table 1 and verifies
//! the orderings the rest of the evaluation depends on (measured back
//! from the simulator itself).

use metall_rs::bench_util::{record, Table};
use metall_rs::storage::netfs::{profile_by_name_strict, SimNetFs};
use metall_rs::util::jsonw::JsonObj;

fn main() {
    let mut t = Table::new(&["device", "op latency", "bandwidth", "concurrency", "metadata op"]);
    for name in ["optane", "nvme", "vast", "lustre"] {
        // strict lookup: a typo here aborts listing the known profiles
        let p = profile_by_name_strict(name).expect("known profile");
        t.row(&[
            p.name.to_string(),
            format!("{:.1} us", p.op_latency * 1e6),
            format!("{:.1} GB/s", p.bandwidth / 1e9),
            p.concurrency.to_string(),
            format!("{:.1} us", p.metadata_latency * 1e6),
        ]);
        record(
            "table1_devices",
            JsonObj::new()
                .str("device", p.name)
                .num("op_latency_s", p.op_latency)
                .num("bandwidth_Bps", p.bandwidth)
                .int("concurrency", p.concurrency as i64)
                .num("metadata_latency_s", p.metadata_latency),
        );
    }
    t.print("Table 1: device cost model (derived from paper Table 1)");

    // measured sanity of the model: latency ordering and bandwidth ordering
    let lat = |n: &str| SimNetFs::new(profile_by_name_strict(n).unwrap()).charge_io(1, 0, 1);
    assert!(lat("optane") < lat("nvme"), "optane beats nvme on latency");
    assert!(lat("nvme") < lat("vast"), "local beats network on latency");
    assert!(lat("vast") < lat("lustre"), "vast is the latency-oriented PFS");
    let bw = |n: &str| SimNetFs::new(profile_by_name_strict(n).unwrap()).charge_io(0, 1 << 30, 16);
    assert!(bw("lustre") < bw("vast"), "lustre is the throughput-oriented PFS");
    println!("\norderings verified: optane < nvme < vast < lustre (latency); lustre > vast (bandwidth)");
}

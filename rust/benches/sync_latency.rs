//! Sync-latency benchmark for the incremental, shard-parallel persist
//! path: a (store size × dirty fraction) matrix over one `MetallManager`,
//! plus an **inline-vs-background** ingest-stall comparison for the
//! watermark-driven async flusher.
//!
//! Each size cell builds a store of ≥ `size` MiB of live small objects,
//! times the **first full sync** (every management section + the whole
//! data extent), then times **incremental syncs** that dirty a given
//! permille of the chunks (plus one alloc/free pair, so the management
//! delta path runs too) and a **no-op sync** (nothing dirty at all). The
//! fig5-style acceptance bar: with ≤ 1 % of chunks dirtied on a
//! ≥ 64 MiB store, the incremental sync completes ≥ 5× faster than the
//! full one, and the no-op sync writes zero section bytes.
//!
//! The background mode then replays the 1 %-dirty shape two ways on the
//! first size: **inline** — the ingest thread dirties 1 % of the chunks
//! and calls `sync()` itself each round, eating the full flush latency —
//! and **background** — the same writes with a dirty-byte watermark
//! driving the flusher thread, where the ingest thread's only stall is
//! backpressure. Acceptance bar: background ingest-thread stall ≤ 25 %
//! of the inline stall at the 64 MiB / 1 %-dirty shape.
//!
//! A **simulated-backend matrix** then replays the fig5 incremental
//! shape against the [`metall_rs::storage::netfs`] cost models
//! (default lustre + vast, `sleep_scale = 1.0` so the modelled backend
//! really paces the threads), pipelined (depth 2, `sync_async` per
//! round + one final wait) vs serial (depth 1, blocking `sync()` per
//! round). The measure is the sync stall the ingest loop observes on
//! the persist path; acceptance bar on lustre: pipelined ≤ 0.7× serial.
//! Each pipelined cell also reports the bandwidth-adaptive watermark
//! against the profile's bandwidth-delay product (bar: within 2×).
//!
//! Results go to the human table, to `bench_results/sync_latency.jsonl`,
//! and to `BENCH_sync.json` / `BENCH_sync_netfs.json` at the repo root —
//! each written twice, a `"status": "started"` stub up front and the
//! full document at the end, so every run leaves a machine-readable
//! trace even if interrupted.
//!
//! `cargo bench --bench sync_latency -- [--sizes-mb 64,256]
//!  [--permille 10,0] [--repeats 3] [--bg-rounds 12]
//!  [--netfs-profiles lustre,vast] [--netfs-rounds 8] [--netfs-mb 24]
//!  [--netfs-compute-ms 40]`

use std::collections::HashMap;
use std::path::Path;

use metall_rs::alloc::{ManagerOptions, MetallManager};
use metall_rs::bench_util::{record, BenchArgs, Table};
use metall_rs::storage::netfs;
use metall_rs::telemetry::export::OpLatency;
use metall_rs::util::human;
use metall_rs::util::jsonw::JsonObj;
use metall_rs::util::tmp::TempDir;

const CHUNK: usize = 256 << 10; // 256 KiB: a 64 MiB store has 256 chunks
const OUT: &str = "BENCH_sync.json";
const OUT_NETFS: &str = "BENCH_sync_netfs.json";

struct Cell {
    size_mb: usize,
    phase: String,
    secs: f64,
    dirty_sections: u64,
    total_sections: u64,
    section_bytes: u64,
    data_chunks: u64,
    data_bytes: u64,
    cache_slots: u64,
}

/// Build a `mb`-MiB store of fully written 64 KiB objects; returns the
/// manager, one representative offset per chunk (sorted), and the chunk
/// count.
fn build_store(
    dir: &Path,
    mb: usize,
    configure: impl FnOnce(&mut ManagerOptions),
) -> anyhow::Result<(MetallManager, Vec<u64>, usize)> {
    let mut opts = ManagerOptions {
        chunk_size: CHUNK,
        file_size: 8 << 20,
        vm_reserve: (4usize << 30).max(4 * mb << 20),
        ..Default::default()
    };
    configure(&mut opts);
    let m = MetallManager::create_with(dir, opts)?;
    // 64 KiB objects (4 per chunk), fully written so the first sync
    // flushes everything
    let obj = CHUNK / 4;
    let mut rep_of_chunk: HashMap<usize, u64> = HashMap::new();
    while m.used_segment_bytes() < mb << 20 {
        let off = m.allocate(obj)?;
        unsafe { m.bytes_mut(off, obj).fill(0x5A) };
        rep_of_chunk.entry(off as usize / CHUNK).or_insert(off);
    }
    let nchunks = m.used_segment_bytes() / CHUNK;
    let mut reps: Vec<u64> = rep_of_chunk.values().copied().collect();
    reps.sort_unstable();
    Ok((m, reps, nchunks))
}

/// One simulated-backend cell: the fig5 incremental shape against a
/// [`metall_rs::storage::netfs`] cost model, either strictly serial
/// (depth 1, blocking `sync()` per round) or pipelined (depth 2,
/// `sync_async` per round + one final wait). `sync_stall_secs` is the
/// time the ingest loop spent on the persist path.
struct NetCell {
    profile: String,
    mode: &'static str,
    sync_stall_secs: f64,
    wall_secs: f64,
    sim_secs: f64,
    epochs_committed: u64,
    peak_in_flight: u64,
    adaptive_watermark_bytes: u64,
    measured_bandwidth_bps: u64,
}

fn netfs_cell(
    work: &TempDir,
    profile: &str,
    pipelined: bool,
    mb: usize,
    rounds: usize,
    compute_ms: u64,
) -> anyhow::Result<NetCell> {
    let mode = if pipelined { "pipelined" } else { "serial" };
    let dir = work.join(&format!("netfs-{profile}-{mode}"));
    let (m, reps, nchunks) = build_store(&dir, mb, |o| {
        o.netfs_profile = Some(profile.to_string());
        o.netfs_sleep_scale = 1.0; // the modelled backend really paces us
        o.sync_pipeline_depth = if pipelined { 2 } else { 1 };
    })?;
    m.sync()?; // first full sync off the measured path
    let dirty_per_round = (nchunks / 100).clamp(1, 8);
    let sim0 = m.netfs().map(|n| n.sim_seconds()).unwrap_or(0.0);
    let t_all = std::time::Instant::now();
    let mut stall = 0.0f64;
    let mut last = None;
    for round in 0..rounds {
        for i in 0..dirty_per_round {
            let off = reps[(round * dirty_per_round + i) % reps.len()];
            m.write::<u64>(off, round as u64);
        }
        let tmp = m.allocate(64)?;
        m.deallocate(tmp)?; // fig5's management-delta shape
        let t0 = std::time::Instant::now();
        if pipelined {
            last = Some(m.sync_async()?);
        } else {
            m.sync()?;
        }
        stall += t0.elapsed().as_secs_f64();
        // Modelled ingest compute between flush points — the window the
        // pipelined engine hides its backend writes behind. The serial
        // mode gets the identical window; it just cannot overlap it.
        std::thread::sleep(std::time::Duration::from_millis(compute_ms));
    }
    if let Some(t) = last {
        let t0 = std::time::Instant::now();
        t.wait()?;
        stall += t0.elapsed().as_secs_f64();
    }
    let wall_secs = t_all.elapsed().as_secs_f64();
    let sim_secs = m.netfs().map(|n| n.sim_seconds()).unwrap_or(0.0) - sim0;
    let bg = m.bg_sync_stats();
    m.close().map_err(|e| anyhow::anyhow!("{e}"))?;
    let _ = std::fs::remove_dir_all(&dir);
    Ok(NetCell {
        profile: profile.to_string(),
        mode,
        sync_stall_secs: stall,
        wall_secs,
        sim_secs,
        epochs_committed: bg.epochs_committed,
        peak_in_flight: bg.pipeline_peak_in_flight,
        adaptive_watermark_bytes: bg.adaptive_watermark_bytes,
        measured_bandwidth_bps: bg.measured_bandwidth_bps,
    })
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let sizes_mb = args.get_usize_list("sizes-mb", &[64]);
    let permille = args.get_usize_list("permille", &[10, 0]);
    let repeats = args.get_usize("repeats", 3).max(1);
    let bg_rounds = args.get_usize("bg-rounds", 12).max(1);
    let netfs_profiles: Vec<String> = args
        .get("netfs-profiles")
        .unwrap_or("lustre,vast")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let netfs_rounds = args.get_usize("netfs-rounds", 8).max(2);
    let netfs_mb = args.get_usize("netfs-mb", 24).max(8);
    let netfs_compute_ms = args.get_usize("netfs-compute-ms", 40) as u64;
    // unknown profile names fail fast, before any store is built
    for p in &netfs_profiles {
        netfs::profile_by_name_strict(p).map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    let work = TempDir::new("sync-latency");

    // the trajectory files must exist whatever happens after this point
    let stub = JsonObj::new()
        .str("bench", "sync_latency")
        .str("status", "started")
        .raw("results", "[]")
        .finish();
    std::fs::write(OUT, stub + "\n")?;
    let stub = JsonObj::new()
        .str("bench", "sync_latency_netfs")
        .str("status", "started")
        .raw("results", "[]")
        .raw("profiles", "[]")
        .finish();
    std::fs::write(OUT_NETFS, stub + "\n")?;

    let mut t = Table::new(&[
        "size", "phase", "time", "vs full", "dirty sects", "sect bytes", "data chunks",
        "data bytes",
    ]);
    let mut cells: Vec<Cell> = Vec::new();
    let mut lat_rows: Vec<(usize, OpLatency)> = Vec::new();
    let mut speedup_1pct: Option<f64> = None;
    let mut noop_section_bytes: Option<u64> = None;
    let mut noop_data_chunks: Option<u64> = None;

    for &mb in &sizes_mb {
        let dir = work.join(&format!("s{mb}"));
        let (m, reps, nchunks) = build_store(&dir, mb, |_| {})?;

        // first full sync: every section + the whole data extent
        let t0 = std::time::Instant::now();
        m.sync()?;
        let full_secs = t0.elapsed().as_secs_f64();
        let full_stats = m.sync_stats();
        let full = Cell {
            size_mb: mb,
            phase: "full".into(),
            secs: full_secs,
            dirty_sections: full_stats.dirty_sections,
            total_sections: full_stats.total_sections,
            section_bytes: full_stats.section_bytes_written,
            data_chunks: full_stats.data_chunks_flushed,
            data_bytes: full_stats.data_bytes_flushed,
            cache_slots: full_stats.cache_slots_preserved,
        };

        for &pm in &permille {
            let dirty_chunks = if pm == 0 { 0 } else { (nchunks * pm / 1000).max(1) };
            let mut best = f64::INFINITY;
            let mut stats = m.sync_stats();
            for _ in 0..repeats {
                if pm > 0 {
                    // dirty a permille of the chunks (one 8-byte write
                    // each) plus an alloc/free pair so bin/cache dirty
                    // tracking runs — the fig5 incremental shape
                    for &off in reps.iter().take(dirty_chunks) {
                        m.write::<u64>(off, 0xD117);
                    }
                    let tmp = m.allocate(64)?;
                    m.deallocate(tmp)?;
                }
                let t0 = std::time::Instant::now();
                m.sync()?;
                let secs = t0.elapsed().as_secs_f64();
                if secs < best {
                    best = secs;
                    stats = m.sync_stats();
                }
            }
            let phase = if pm == 0 { "noop".into() } else { format!("permille_{pm}") };
            if pm == 10 && mb >= 64 && speedup_1pct.is_none() {
                speedup_1pct = Some(full_secs / best);
            }
            if pm == 0 {
                noop_section_bytes = Some(stats.section_bytes_written);
                noop_data_chunks = Some(stats.data_chunks_flushed);
            }
            cells.push(Cell {
                size_mb: mb,
                phase,
                secs: best,
                dirty_sections: stats.dirty_sections,
                total_sections: stats.total_sections,
                section_bytes: stats.section_bytes_written,
                data_chunks: stats.data_chunks_flushed,
                data_bytes: stats.data_bytes_flushed,
                cache_slots: stats.cache_slots_preserved,
            });
        }
        cells.push(full);
        // cells were pushed incremental-first; order the table full-first
        cells.sort_by_key(|c| (c.size_mb, c.phase != "full"));
        // per-op tail latencies from the always-on telemetry histograms
        // (alloc paths sampled 1-in-64; epoch phases unsampled)
        for (op, snap) in m.latency_snapshot() {
            if snap.count > 0 {
                lat_rows.push((mb, OpLatency::from_snapshot(op, &snap)));
            }
        }
        m.close().map_err(|e| anyhow::anyhow!("{e}"))?;
        let _ = std::fs::remove_dir_all(&dir);
    }

    // ---- ingest-thread stall: inline sync() vs background flusher ----
    // The fig5 shape at the first size: each round dirties ~1 % of the
    // chunks. Inline pays the full sync() latency on the ingest thread
    // every round; background only ever stalls at the backpressure
    // ceiling while the watermark-driven flusher persists concurrently.
    let mb = sizes_mb.first().copied().unwrap_or(64);
    let inline_stall = {
        let dir = work.join("bg-inline");
        let (m, reps, nchunks) = build_store(&dir, mb, |_| {})?;
        let dirty_per_round = (nchunks / 100).max(1);
        m.sync()?; // first full sync off the measured path
        let mut stall = 0.0f64;
        for round in 0..bg_rounds {
            for i in 0..dirty_per_round {
                let off = reps[(round * dirty_per_round + i) % reps.len()];
                m.write::<u64>(off, round as u64);
            }
            let t0 = std::time::Instant::now();
            m.sync()?;
            stall += t0.elapsed().as_secs_f64();
        }
        m.close().map_err(|e| anyhow::anyhow!("{e}"))?;
        let _ = std::fs::remove_dir_all(&dir);
        stall
    };
    let (bg_stall, bg_flushes, bg_watermark_hits) = {
        let dir = work.join("bg-async");
        let (m, reps, nchunks) = build_store(&dir, mb, |o| {
            // one dirty chunk crosses the watermark: the flusher chases
            // the ingest thread round by round
            o.sync_watermark_bytes = CHUNK;
        })?;
        let dirty_per_round = (nchunks / 100).max(1);
        m.sync()?; // first full sync off the measured path
        let stall_before = m.bg_sync_stats().writer_stall_micros;
        for round in 0..bg_rounds {
            for i in 0..dirty_per_round {
                let off = reps[(round * dirty_per_round + i) % reps.len()];
                m.write::<u64>(off, round as u64);
            }
            // no sync() call: the watermark drives the flusher
        }
        let bgstats = m.bg_sync_stats();
        let out = (
            (bgstats.writer_stall_micros - stall_before) as f64 / 1e6,
            bgstats.flushes,
            bgstats.watermark_triggers,
        );
        m.close().map_err(|e| anyhow::anyhow!("{e}"))?;
        let _ = std::fs::remove_dir_all(&dir);
        out
    };
    let bg_stall_ratio = bg_stall / inline_stall.max(1e-9);
    cells.push(Cell {
        size_mb: mb,
        phase: "inline_1pct_stall".into(),
        secs: inline_stall,
        dirty_sections: 0,
        total_sections: 0,
        section_bytes: 0,
        data_chunks: 0,
        data_bytes: 0,
        cache_slots: 0,
    });
    cells.push(Cell {
        size_mb: mb,
        phase: "background_1pct_stall".into(),
        secs: bg_stall,
        dirty_sections: 0,
        total_sections: 0,
        section_bytes: 0,
        data_chunks: 0,
        data_bytes: 0,
        cache_slots: 0,
    });

    // ---- simulated-backend matrix: profile × serial-vs-pipelined ----
    let mut netcells: Vec<NetCell> = Vec::new();
    for p in &netfs_profiles {
        for pipelined in [false, true] {
            netcells.push(netfs_cell(
                &work,
                p,
                pipelined,
                netfs_mb,
                netfs_rounds,
                netfs_compute_ms,
            )?);
        }
    }

    for c in &cells {
        let vs_full = cells
            .iter()
            .find(|f| f.size_mb == c.size_mb && f.phase == "full")
            .map(|f| {
                if c.secs > 0.0 { format!("{:.1}x", f.secs / c.secs) } else { "-".into() }
            })
            .unwrap_or_else(|| "-".into());
        t.row(&[
            format!("{} MiB", c.size_mb),
            c.phase.clone(),
            human::duration(c.secs),
            vs_full,
            format!("{}/{}", c.dirty_sections, c.total_sections),
            human::bytes(c.section_bytes),
            c.data_chunks.to_string(),
            human::bytes(c.data_bytes),
        ]);
        record(
            "sync_latency",
            JsonObj::new()
                .str("bench", "sync-latency")
                .int("size_mb", c.size_mb as i64)
                .str("phase", &c.phase)
                .num("secs", c.secs)
                .int("dirty_sections", c.dirty_sections as i64)
                .int("total_sections", c.total_sections as i64)
                .int("section_bytes", c.section_bytes as i64)
                .int("data_chunks", c.data_chunks as i64)
                .int("data_bytes", c.data_bytes as i64)
                .int("cache_slots_preserved", c.cache_slots as i64),
        );
    }
    t.print("incremental sync: store size × dirty fraction (first sync = full store)");

    let mut lt = Table::new(&["size", "op", "samples", "p50", "p90", "p99", "p999"]);
    for (mb, l) in &lat_rows {
        lt.row(&[
            format!("{mb} MiB"),
            l.op.to_string(),
            l.count.to_string(),
            human::duration(l.p50 as f64 / 1e9),
            human::duration(l.p90 as f64 / 1e9),
            human::duration(l.p99 as f64 / 1e9),
            human::duration(l.p999 as f64 / 1e9),
        ]);
        record(
            "sync_latency",
            JsonObj::new()
                .str("bench", "sync-latency-quantiles")
                .int("size_mb", *mb as i64)
                .str("op", l.op)
                .int("count", l.count as i64)
                .int("p50_ns", l.p50 as i64)
                .int("p90_ns", l.p90 as i64)
                .int("p99_ns", l.p99 as i64)
                .int("p999_ns", l.p999 as i64),
        );
    }
    lt.print("per-op latency quantiles (telemetry histograms; alloc paths sampled 1-in-64)");
    if let Some(sp) = speedup_1pct {
        println!(
            "\nincremental speedup at 1% dirty on the ≥64 MiB store: {sp:.1}x \
             (acceptance bar ≥ 5x)"
        );
    }
    if let (Some(sb), Some(dc)) = (noop_section_bytes, noop_data_chunks) {
        println!("no-op sync: {sb} section bytes, {dc} data chunks (bar: 0 and 0)");
    }
    println!(
        "background ingest stall: {} vs inline {} over {bg_rounds} rounds \
         = {:.1}% of inline (bar ≤ 25%); {bg_flushes} background flushes, \
         {bg_watermark_hits} watermark hits",
        human::duration(bg_stall),
        human::duration(inline_stall),
        bg_stall_ratio * 100.0
    );

    let mut rows = String::from("[");
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            rows.push(',');
        }
        rows.push_str(
            &JsonObj::new()
                .int("size_mb", c.size_mb as i64)
                .str("phase", &c.phase)
                .num("secs", c.secs)
                .int("dirty_sections", c.dirty_sections as i64)
                .int("total_sections", c.total_sections as i64)
                .int("section_bytes", c.section_bytes as i64)
                .int("data_chunks", c.data_chunks as i64)
                .int("data_bytes", c.data_bytes as i64)
                .int("cache_slots_preserved", c.cache_slots as i64)
                .finish(),
        );
    }
    rows.push(']');
    let mut lats = String::from("[");
    for (i, (mb, l)) in lat_rows.iter().enumerate() {
        if i > 0 {
            lats.push(',');
        }
        lats.push_str(
            &JsonObj::new()
                .int("size_mb", *mb as i64)
                .str("op", l.op)
                .int("count", l.count as i64)
                .int("p50_ns", l.p50 as i64)
                .int("p90_ns", l.p90 as i64)
                .int("p99_ns", l.p99 as i64)
                .int("p999_ns", l.p999 as i64)
                .finish(),
        );
    }
    lats.push(']');
    let mut doc = JsonObj::new()
        .str("bench", "sync_latency")
        .str("status", "complete")
        .str(
            "workload",
            "64KiB objects, full-store first sync vs permille-dirty incremental syncs, \
             plus inline-vs-background ingest-thread stall at the 1%-dirty shape",
        )
        .int("chunk_size", CHUNK as i64)
        .int("repeats", repeats as i64)
        .int("bg_rounds", bg_rounds as i64)
        .num("inline_stall_secs", inline_stall)
        .num("background_stall_secs", bg_stall)
        .num("background_stall_ratio", bg_stall_ratio)
        .int("background_flushes", bg_flushes as i64)
        .int("background_watermark_hits", bg_watermark_hits as i64)
        .raw("results", &rows)
        .raw("latency_ns", &lats);
    if let Some(sp) = speedup_1pct {
        doc = doc.num("incremental_speedup_1pct", sp);
    }
    if let Some(sb) = noop_section_bytes {
        doc = doc.int("noop_section_bytes", sb as i64);
    }
    if let Some(dc) = noop_data_chunks {
        doc = doc.int("noop_data_chunks", dc as i64);
    }
    std::fs::write(OUT, doc.finish() + "\n")?;
    println!("wrote {OUT}");

    // ---- simulated-backend matrix: table + BENCH_sync_netfs.json ----
    let mut nt = Table::new(&[
        "backend", "mode", "sync stall", "wall", "sim io", "epochs", "peak", "adaptive wm",
        "meas bw",
    ]);
    for c in &netcells {
        nt.row(&[
            c.profile.clone(),
            c.mode.to_string(),
            human::duration(c.sync_stall_secs),
            human::duration(c.wall_secs),
            human::duration(c.sim_secs),
            c.epochs_committed.to_string(),
            c.peak_in_flight.to_string(),
            human::bytes(c.adaptive_watermark_bytes),
            human::rate(c.measured_bandwidth_bps as f64),
        ]);
        record(
            "sync_latency",
            JsonObj::new()
                .str("bench", "sync-netfs")
                .str("profile", &c.profile)
                .str("mode", c.mode)
                .num("sync_stall_secs", c.sync_stall_secs)
                .num("wall_secs", c.wall_secs)
                .num("sim_secs", c.sim_secs)
                .int("epochs_committed", c.epochs_committed as i64)
                .int("pipeline_peak_in_flight", c.peak_in_flight as i64)
                .int("adaptive_watermark_bytes", c.adaptive_watermark_bytes as i64)
                .int("measured_bandwidth_bps", c.measured_bandwidth_bps as i64),
        );
    }
    nt.print(&format!(
        "simulated backends: fig5 incremental shape, {netfs_rounds} rounds × \
         {netfs_compute_ms} ms modelled ingest compute, serial vs pipelined"
    ));

    let mut nrows = String::from("[");
    for (i, c) in netcells.iter().enumerate() {
        if i > 0 {
            nrows.push(',');
        }
        nrows.push_str(
            &JsonObj::new()
                .str("profile", &c.profile)
                .str("mode", c.mode)
                .num("sync_stall_secs", c.sync_stall_secs)
                .num("wall_secs", c.wall_secs)
                .num("sim_secs", c.sim_secs)
                .int("epochs_committed", c.epochs_committed as i64)
                .int("pipeline_peak_in_flight", c.peak_in_flight as i64)
                .int("adaptive_watermark_bytes", c.adaptive_watermark_bytes as i64)
                .int("measured_bandwidth_bps", c.measured_bandwidth_bps as i64)
                .finish(),
        );
    }
    nrows.push(']');
    let mut summaries = String::from("[");
    for (i, p) in netfs_profiles.iter().enumerate() {
        let bdp = netfs::profile_by_name_strict(p)
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .bdp_bytes();
        let serial = netcells.iter().find(|c| &c.profile == p && c.mode == "serial");
        let piped = netcells.iter().find(|c| &c.profile == p && c.mode == "pipelined");
        let (serial, piped) = match (serial, piped) {
            (Some(s), Some(pl)) => (s, pl),
            _ => continue,
        };
        let ratio = piped.sync_stall_secs / serial.sync_stall_secs.max(1e-9);
        let wm_over_bdp = piped.adaptive_watermark_bytes as f64 / bdp.max(1) as f64;
        println!(
            "{p}: pipelined sync stall {} vs serial {} = {:.2}x (bar ≤ 0.7 on lustre); \
             adaptive watermark {} vs BDP {} = {:.2}x (bar within 2x)",
            human::duration(piped.sync_stall_secs),
            human::duration(serial.sync_stall_secs),
            ratio,
            human::bytes(piped.adaptive_watermark_bytes),
            human::bytes(bdp),
            wm_over_bdp
        );
        if i > 0 {
            summaries.push(',');
        }
        summaries.push_str(
            &JsonObj::new()
                .str("profile", p)
                .int("bdp_bytes", bdp as i64)
                .num("serial_sync_stall_secs", serial.sync_stall_secs)
                .num("pipelined_sync_stall_secs", piped.sync_stall_secs)
                .num("pipelined_over_serial_sync_ratio", ratio)
                .int("adaptive_watermark_bytes", piped.adaptive_watermark_bytes as i64)
                .num("watermark_over_bdp", wm_over_bdp)
                .int("measured_bandwidth_bps", piped.measured_bandwidth_bps as i64)
                .finish(),
        );
    }
    summaries.push(']');
    let ndoc = JsonObj::new()
        .str("bench", "sync_latency_netfs")
        .str("status", "complete")
        .str(
            "workload",
            "fig5 incremental shape against the netfs cost models (sleep_scale=1.0): \
             per round dirty ~1% of chunks + one alloc/free, then blocking sync() \
             (serial, depth 1) vs sync_async + one final wait (pipelined, depth 2), \
             with fixed modelled ingest compute between flush points",
        )
        .int("chunk_size", CHUNK as i64)
        .int("store_mb", netfs_mb as i64)
        .int("rounds", netfs_rounds as i64)
        .int("compute_ms", netfs_compute_ms as i64)
        .num("background_stall_ratio", bg_stall_ratio)
        .raw("results", &nrows)
        .raw("profiles", &summaries);
    std::fs::write(OUT_NETFS, ndoc.finish() + "\n")?;
    println!("wrote {OUT_NETFS}");
    Ok(())
}

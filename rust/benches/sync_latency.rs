//! Sync-latency benchmark for the incremental, shard-parallel persist
//! path: a (store size × dirty fraction) matrix over one `MetallManager`,
//! plus an **inline-vs-background** ingest-stall comparison for the
//! watermark-driven async flusher.
//!
//! Each size cell builds a store of ≥ `size` MiB of live small objects,
//! times the **first full sync** (every management section + the whole
//! data extent), then times **incremental syncs** that dirty a given
//! permille of the chunks (plus one alloc/free pair, so the management
//! delta path runs too) and a **no-op sync** (nothing dirty at all). The
//! fig5-style acceptance bar: with ≤ 1 % of chunks dirtied on a
//! ≥ 64 MiB store, the incremental sync completes ≥ 5× faster than the
//! full one, and the no-op sync writes zero section bytes.
//!
//! The background mode then replays the 1 %-dirty shape two ways on the
//! first size: **inline** — the ingest thread dirties 1 % of the chunks
//! and calls `sync()` itself each round, eating the full flush latency —
//! and **background** — the same writes with a dirty-byte watermark
//! driving the flusher thread, where the ingest thread's only stall is
//! backpressure. Acceptance bar: background ingest-thread stall ≤ 25 %
//! of the inline stall at the 64 MiB / 1 %-dirty shape.
//!
//! Results go to the human table, to `bench_results/sync_latency.jsonl`,
//! and to `BENCH_sync.json` at the repo root — written twice, a
//! `"status": "started"` stub up front and the full document at the end,
//! so every run leaves a machine-readable trace even if interrupted.
//!
//! `cargo bench --bench sync_latency -- [--sizes-mb 64,256]
//!  [--permille 10,0] [--repeats 3] [--bg-rounds 12]`

use std::collections::HashMap;
use std::path::Path;

use metall_rs::alloc::{ManagerOptions, MetallManager};
use metall_rs::bench_util::{record, BenchArgs, Table};
use metall_rs::util::human;
use metall_rs::util::jsonw::JsonObj;
use metall_rs::util::tmp::TempDir;

const CHUNK: usize = 256 << 10; // 256 KiB: a 64 MiB store has 256 chunks
const OUT: &str = "BENCH_sync.json";

struct Cell {
    size_mb: usize,
    phase: String,
    secs: f64,
    dirty_sections: u64,
    total_sections: u64,
    section_bytes: u64,
    data_chunks: u64,
    data_bytes: u64,
    cache_slots: u64,
}

/// Build a `mb`-MiB store of fully written 64 KiB objects; returns the
/// manager, one representative offset per chunk (sorted), and the chunk
/// count.
fn build_store(
    dir: &Path,
    mb: usize,
    configure: impl FnOnce(&mut ManagerOptions),
) -> anyhow::Result<(MetallManager, Vec<u64>, usize)> {
    let mut opts = ManagerOptions {
        chunk_size: CHUNK,
        file_size: 8 << 20,
        vm_reserve: (4usize << 30).max(4 * mb << 20),
        ..Default::default()
    };
    configure(&mut opts);
    let m = MetallManager::create_with(dir, opts)?;
    // 64 KiB objects (4 per chunk), fully written so the first sync
    // flushes everything
    let obj = CHUNK / 4;
    let mut rep_of_chunk: HashMap<usize, u64> = HashMap::new();
    while m.used_segment_bytes() < mb << 20 {
        let off = m.allocate(obj)?;
        unsafe { m.bytes_mut(off, obj).fill(0x5A) };
        rep_of_chunk.entry(off as usize / CHUNK).or_insert(off);
    }
    let nchunks = m.used_segment_bytes() / CHUNK;
    let mut reps: Vec<u64> = rep_of_chunk.values().copied().collect();
    reps.sort_unstable();
    Ok((m, reps, nchunks))
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let sizes_mb = args.get_usize_list("sizes-mb", &[64]);
    let permille = args.get_usize_list("permille", &[10, 0]);
    let repeats = args.get_usize("repeats", 3).max(1);
    let bg_rounds = args.get_usize("bg-rounds", 12).max(1);
    let work = TempDir::new("sync-latency");

    // the trajectory file must exist whatever happens after this point
    let stub = JsonObj::new()
        .str("bench", "sync_latency")
        .str("status", "started")
        .raw("results", "[]")
        .finish();
    std::fs::write(OUT, stub + "\n")?;

    let mut t = Table::new(&[
        "size", "phase", "time", "vs full", "dirty sects", "sect bytes", "data chunks",
        "data bytes",
    ]);
    let mut cells: Vec<Cell> = Vec::new();
    let mut speedup_1pct: Option<f64> = None;
    let mut noop_section_bytes: Option<u64> = None;
    let mut noop_data_chunks: Option<u64> = None;

    for &mb in &sizes_mb {
        let dir = work.join(&format!("s{mb}"));
        let (m, reps, nchunks) = build_store(&dir, mb, |_| {})?;

        // first full sync: every section + the whole data extent
        let t0 = std::time::Instant::now();
        m.sync()?;
        let full_secs = t0.elapsed().as_secs_f64();
        let full_stats = m.sync_stats();
        let full = Cell {
            size_mb: mb,
            phase: "full".into(),
            secs: full_secs,
            dirty_sections: full_stats.dirty_sections,
            total_sections: full_stats.total_sections,
            section_bytes: full_stats.section_bytes_written,
            data_chunks: full_stats.data_chunks_flushed,
            data_bytes: full_stats.data_bytes_flushed,
            cache_slots: full_stats.cache_slots_preserved,
        };

        for &pm in &permille {
            let dirty_chunks = if pm == 0 { 0 } else { (nchunks * pm / 1000).max(1) };
            let mut best = f64::INFINITY;
            let mut stats = m.sync_stats();
            for _ in 0..repeats {
                if pm > 0 {
                    // dirty a permille of the chunks (one 8-byte write
                    // each) plus an alloc/free pair so bin/cache dirty
                    // tracking runs — the fig5 incremental shape
                    for &off in reps.iter().take(dirty_chunks) {
                        m.write::<u64>(off, 0xD117);
                    }
                    let tmp = m.allocate(64)?;
                    m.deallocate(tmp)?;
                }
                let t0 = std::time::Instant::now();
                m.sync()?;
                let secs = t0.elapsed().as_secs_f64();
                if secs < best {
                    best = secs;
                    stats = m.sync_stats();
                }
            }
            let phase = if pm == 0 { "noop".into() } else { format!("permille_{pm}") };
            if pm == 10 && mb >= 64 && speedup_1pct.is_none() {
                speedup_1pct = Some(full_secs / best);
            }
            if pm == 0 {
                noop_section_bytes = Some(stats.section_bytes_written);
                noop_data_chunks = Some(stats.data_chunks_flushed);
            }
            cells.push(Cell {
                size_mb: mb,
                phase,
                secs: best,
                dirty_sections: stats.dirty_sections,
                total_sections: stats.total_sections,
                section_bytes: stats.section_bytes_written,
                data_chunks: stats.data_chunks_flushed,
                data_bytes: stats.data_bytes_flushed,
                cache_slots: stats.cache_slots_preserved,
            });
        }
        cells.push(full);
        // cells were pushed incremental-first; order the table full-first
        cells.sort_by_key(|c| (c.size_mb, c.phase != "full"));
        m.close().map_err(|e| anyhow::anyhow!("{e}"))?;
        let _ = std::fs::remove_dir_all(&dir);
    }

    // ---- ingest-thread stall: inline sync() vs background flusher ----
    // The fig5 shape at the first size: each round dirties ~1 % of the
    // chunks. Inline pays the full sync() latency on the ingest thread
    // every round; background only ever stalls at the backpressure
    // ceiling while the watermark-driven flusher persists concurrently.
    let mb = sizes_mb.first().copied().unwrap_or(64);
    let inline_stall = {
        let dir = work.join("bg-inline");
        let (m, reps, nchunks) = build_store(&dir, mb, |_| {})?;
        let dirty_per_round = (nchunks / 100).max(1);
        m.sync()?; // first full sync off the measured path
        let mut stall = 0.0f64;
        for round in 0..bg_rounds {
            for i in 0..dirty_per_round {
                let off = reps[(round * dirty_per_round + i) % reps.len()];
                m.write::<u64>(off, round as u64);
            }
            let t0 = std::time::Instant::now();
            m.sync()?;
            stall += t0.elapsed().as_secs_f64();
        }
        m.close().map_err(|e| anyhow::anyhow!("{e}"))?;
        let _ = std::fs::remove_dir_all(&dir);
        stall
    };
    let (bg_stall, bg_flushes, bg_watermark_hits) = {
        let dir = work.join("bg-async");
        let (m, reps, nchunks) = build_store(&dir, mb, |o| {
            // one dirty chunk crosses the watermark: the flusher chases
            // the ingest thread round by round
            o.sync_watermark_bytes = CHUNK;
        })?;
        let dirty_per_round = (nchunks / 100).max(1);
        m.sync()?; // first full sync off the measured path
        let stall_before = m.bg_sync_stats().writer_stall_micros;
        for round in 0..bg_rounds {
            for i in 0..dirty_per_round {
                let off = reps[(round * dirty_per_round + i) % reps.len()];
                m.write::<u64>(off, round as u64);
            }
            // no sync() call: the watermark drives the flusher
        }
        let bgstats = m.bg_sync_stats();
        let out = (
            (bgstats.writer_stall_micros - stall_before) as f64 / 1e6,
            bgstats.flushes,
            bgstats.watermark_triggers,
        );
        m.close().map_err(|e| anyhow::anyhow!("{e}"))?;
        let _ = std::fs::remove_dir_all(&dir);
        out
    };
    let bg_stall_ratio = bg_stall / inline_stall.max(1e-9);
    cells.push(Cell {
        size_mb: mb,
        phase: "inline_1pct_stall".into(),
        secs: inline_stall,
        dirty_sections: 0,
        total_sections: 0,
        section_bytes: 0,
        data_chunks: 0,
        data_bytes: 0,
        cache_slots: 0,
    });
    cells.push(Cell {
        size_mb: mb,
        phase: "background_1pct_stall".into(),
        secs: bg_stall,
        dirty_sections: 0,
        total_sections: 0,
        section_bytes: 0,
        data_chunks: 0,
        data_bytes: 0,
        cache_slots: 0,
    });

    for c in &cells {
        let vs_full = cells
            .iter()
            .find(|f| f.size_mb == c.size_mb && f.phase == "full")
            .map(|f| {
                if c.secs > 0.0 { format!("{:.1}x", f.secs / c.secs) } else { "-".into() }
            })
            .unwrap_or_else(|| "-".into());
        t.row(&[
            format!("{} MiB", c.size_mb),
            c.phase.clone(),
            human::duration(c.secs),
            vs_full,
            format!("{}/{}", c.dirty_sections, c.total_sections),
            human::bytes(c.section_bytes),
            c.data_chunks.to_string(),
            human::bytes(c.data_bytes),
        ]);
        record(
            "sync_latency",
            JsonObj::new()
                .str("bench", "sync-latency")
                .int("size_mb", c.size_mb as i64)
                .str("phase", &c.phase)
                .num("secs", c.secs)
                .int("dirty_sections", c.dirty_sections as i64)
                .int("total_sections", c.total_sections as i64)
                .int("section_bytes", c.section_bytes as i64)
                .int("data_chunks", c.data_chunks as i64)
                .int("data_bytes", c.data_bytes as i64)
                .int("cache_slots_preserved", c.cache_slots as i64),
        );
    }
    t.print("incremental sync: store size × dirty fraction (first sync = full store)");
    if let Some(sp) = speedup_1pct {
        println!(
            "\nincremental speedup at 1% dirty on the ≥64 MiB store: {sp:.1}x \
             (acceptance bar ≥ 5x)"
        );
    }
    if let (Some(sb), Some(dc)) = (noop_section_bytes, noop_data_chunks) {
        println!("no-op sync: {sb} section bytes, {dc} data chunks (bar: 0 and 0)");
    }
    println!(
        "background ingest stall: {} vs inline {} over {bg_rounds} rounds \
         = {:.1}% of inline (bar ≤ 25%); {bg_flushes} background flushes, \
         {bg_watermark_hits} watermark hits",
        human::duration(bg_stall),
        human::duration(inline_stall),
        bg_stall_ratio * 100.0
    );

    let mut rows = String::from("[");
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            rows.push(',');
        }
        rows.push_str(
            &JsonObj::new()
                .int("size_mb", c.size_mb as i64)
                .str("phase", &c.phase)
                .num("secs", c.secs)
                .int("dirty_sections", c.dirty_sections as i64)
                .int("total_sections", c.total_sections as i64)
                .int("section_bytes", c.section_bytes as i64)
                .int("data_chunks", c.data_chunks as i64)
                .int("data_bytes", c.data_bytes as i64)
                .int("cache_slots_preserved", c.cache_slots as i64)
                .finish(),
        );
    }
    rows.push(']');
    let mut doc = JsonObj::new()
        .str("bench", "sync_latency")
        .str("status", "complete")
        .str(
            "workload",
            "64KiB objects, full-store first sync vs permille-dirty incremental syncs, \
             plus inline-vs-background ingest-thread stall at the 1%-dirty shape",
        )
        .int("chunk_size", CHUNK as i64)
        .int("repeats", repeats as i64)
        .int("bg_rounds", bg_rounds as i64)
        .num("inline_stall_secs", inline_stall)
        .num("background_stall_secs", bg_stall)
        .num("background_stall_ratio", bg_stall_ratio)
        .int("background_flushes", bg_flushes as i64)
        .int("background_watermark_hits", bg_watermark_hits as i64)
        .raw("results", &rows);
    if let Some(sp) = speedup_1pct {
        doc = doc.num("incremental_speedup_1pct", sp);
    }
    if let Some(sb) = noop_section_bytes {
        doc = doc.int("noop_section_bytes", sb as i64);
    }
    if let Some(dc) = noop_data_chunks {
        doc = doc.int("noop_data_chunks", dc as i64);
    }
    std::fs::write(OUT, doc.finish() + "\n")?;
    println!("wrote {OUT}");
    Ok(())
}

//! Fig 4 — multi-threaded dynamic graph construction vs. the baseline
//! allocators (paper §6.3). Reproduces both panels:
//!   `--device nvme`   → Fig 4b line-up (metall, bip, pmemkind)
//!   `--device optane` → Fig 4a line-up (+ pmemkind-dontneed, ralloc)
//!
//! `cargo bench --bench fig4_dynamic_graph -- [--device nvme]
//!    [--scales 12,14,16] [--threads 4] [--edge-factor 16]`

use metall_rs::bench_util::{record, BenchArgs, Table};
use metall_rs::experiments::fig4::{run, Fig4Params};
use metall_rs::util::human;
use metall_rs::util::jsonw::JsonObj;
use metall_rs::util::tmp::TempDir;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let device = args.get("device").unwrap_or("nvme").to_string();
    let scales: Vec<u32> = args
        .get("scales")
        .unwrap_or("12,14,16")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let p = Fig4Params {
        scales: scales.clone(),
        threads: args.get_usize("threads", 4),
        edge_factor: args.get_usize("edge-factor", 16),
        device: device.clone(),
        ..Default::default()
    };
    let work = TempDir::new("fig4");
    println!(
        "Fig 4 ({device}): dynamic graph construction, scales {scales:?}, {} threads, edge factor {}",
        p.threads, p.edge_factor
    );

    let rows = run(&p, work.path(), |r| {
        println!(
            "  scale {:>2} {:<20} {:>12} ({})",
            r.scale,
            r.allocator,
            human::duration(r.secs),
            human::rate(r.edges_per_sec)
        );
    })?;

    for &scale in &scales {
        let mut t = Table::new(&["allocator", "time", "edges/s", "metall speedup"]);
        let metall = rows
            .iter()
            .find(|r| r.scale == scale && r.allocator == "metall")
            .unwrap()
            .secs;
        for r in rows.iter().filter(|r| r.scale == scale) {
            t.row(&[
                r.allocator.to_string(),
                human::duration(r.secs),
                human::rate(r.edges_per_sec),
                format!("{:.2}x", r.secs / metall),
            ]);
            record(
                "fig4_dynamic_graph",
                JsonObj::new()
                    .str("device", &device)
                    .str("allocator", r.allocator)
                    .int("scale", r.scale as i64)
                    .int("edges", r.edges as i64)
                    .num("secs", r.secs)
                    .num("edges_per_sec", r.edges_per_sec),
            );
        }
        t.print(&format!("Fig 4 ({device}) — SCALE {scale}"));
    }

    // headline shape check (paper: metall 7.4–11.7x over BIP)
    let last = *scales.last().unwrap();
    let get = |name: &str| rows.iter().find(|r| r.scale == last && r.allocator == name);
    if let (Some(m), Some(b)) = (get("metall"), get("bip")) {
        println!(
            "\nheadline @ SCALE {last}: metall is {:.1}x faster than BIP (paper: 7.4–11.7x)",
            b.secs / m.secs
        );
    }
    Ok(())
}

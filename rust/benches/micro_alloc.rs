//! Allocation-path microbenchmarks across all four allocators: raw
//! alloc/dealloc throughput by size class, object-cache hit rate, and
//! thread scaling — the instrument behind EXPERIMENTS.md §Perf.
//!
//! `cargo bench --bench micro_alloc -- [--ops 200000] [--threads 1,2,4,8]`

use metall_rs::alloc::{ManagerOptions, MetallManager, SegmentAlloc};
use metall_rs::baselines::bip::BipAllocator;
use metall_rs::baselines::pmemkind::{MadvMode, PmemKindAllocator};
use metall_rs::baselines::ralloc_like::RallocLike;
use metall_rs::bench_util::{record, BenchArgs, Table};
use metall_rs::storage::segment::SegmentOptions;
use metall_rs::util::human;
use metall_rs::util::jsonw::JsonObj;
use metall_rs::util::rng::Xoshiro256ss;
use metall_rs::util::tmp::TempDir;

const CHUNK: usize = 1 << 20;

fn seg_opts() -> SegmentOptions {
    SegmentOptions::default().with_file_size(16 << 20).with_vm_reserve(32 << 30)
}

/// Churn workload: allocate/free with a live window, mixed sizes.
fn churn<A: SegmentAlloc>(a: &A, ops: usize, threads: usize, seed: u64) -> f64 {
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for t in 0..threads {
            let a = &a;
            s.spawn(move || {
                let mut rng = Xoshiro256ss::new(seed + t as u64);
                let mut live: Vec<u64> = Vec::with_capacity(256);
                for _ in 0..ops / threads {
                    if live.len() >= 256 || (!live.is_empty() && rng.next_f64() < 0.4) {
                        let i = rng.gen_range(live.len() as u64) as usize;
                        let off = live.swap_remove(i);
                        a.deallocate(off).unwrap();
                    } else {
                        let size = 8 << rng.gen_range(8); // 8..=1024
                        live.push(a.allocate(size as usize).unwrap());
                    }
                }
                for off in live {
                    a.deallocate(off).unwrap();
                }
            });
        }
    });
    t0.elapsed().as_secs_f64()
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let ops = args.get_usize("ops", 200_000);
    let threads = args.get_usize_list("threads", &[1, 2, 4, 8]);
    let work = TempDir::new("micro-alloc");

    let mut t = Table::new(&["allocator", "threads", "time", "ops/s"]);
    for &nt in &threads {
        for name in ["metall", "bip", "pmemkind", "ralloc"] {
            let dir = work.join(&format!("{name}-{nt}"));
            let secs = match name {
                "metall" => {
                    let opts = ManagerOptions {
                        chunk_size: CHUNK,
                        file_size: 16 << 20,
                        vm_reserve: 32 << 30,
                        ..Default::default()
                    };
                    let m = MetallManager::create_with(&dir, opts)?;
                    let s = churn(&m, ops, nt, 1);
                    let st = m.stats();
                    record(
                        "micro_alloc",
                        JsonObj::new()
                            .str("allocator", "metall-cache-stats")
                            .int("threads", nt as i64)
                            .int("allocs", st.allocs as i64)
                            .int("cache_hits", st.cache_hits as i64),
                    );
                    m.close()?;
                    s
                }
                "bip" => {
                    let a = BipAllocator::create_with(&dir, seg_opts())?;
                    churn(&a, ops, nt, 1)
                }
                "pmemkind" => {
                    let a = PmemKindAllocator::create_with(
                        &dir,
                        MadvMode::DontNeed,
                        seg_opts(),
                        CHUNK,
                    )?;
                    churn(&a, ops, nt, 1)
                }
                "ralloc" => {
                    let a = RallocLike::create_with(&dir, seg_opts(), CHUNK)?;
                    churn(&a, ops, nt, 1)
                }
                _ => unreachable!(),
            };
            t.row(&[
                name.to_string(),
                nt.to_string(),
                human::duration(secs),
                human::rate(ops as f64 / secs),
            ]);
            record(
                "micro_alloc",
                JsonObj::new()
                    .str("allocator", name)
                    .int("threads", nt as i64)
                    .int("ops", ops as i64)
                    .num("secs", secs)
                    .num("ops_per_sec", ops as f64 / secs),
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
    t.print("alloc/dealloc churn microbenchmark (mixed sizes 8B–1KiB, 40% frees)");
    Ok(())
}

//! Allocation-path microbenchmarks across all four allocators: raw
//! alloc/dealloc throughput by size class, object-cache hit rate, and
//! thread scaling — the instrument behind EXPERIMENTS.md §Perf.
//!
//! `cargo bench --bench micro_alloc -- [--ops 200000] [--threads 1,2,4,8]`

use metall_rs::alloc::{ManagerOptions, MetallManager, SegmentAlloc};
use metall_rs::baselines::bip::BipAllocator;
use metall_rs::baselines::pmemkind::{MadvMode, PmemKindAllocator};
use metall_rs::baselines::ralloc_like::RallocLike;
use metall_rs::bench_util::{record, BenchArgs, Table};
use metall_rs::storage::segment::SegmentOptions;
use metall_rs::util::human;
use metall_rs::util::jsonw::JsonObj;
use metall_rs::util::rng::Xoshiro256ss;
use metall_rs::util::tmp::TempDir;

const CHUNK: usize = 1 << 20;

fn seg_opts() -> SegmentOptions {
    SegmentOptions::default().with_file_size(16 << 20).with_vm_reserve(32 << 30)
}

/// Churn workload: allocate/free with a live window, mixed sizes.
fn churn<A: SegmentAlloc>(a: &A, ops: usize, threads: usize, seed: u64) -> f64 {
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for t in 0..threads {
            let a = &a;
            s.spawn(move || {
                let mut rng = Xoshiro256ss::new(seed + t as u64);
                let mut live: Vec<u64> = Vec::with_capacity(256);
                for _ in 0..ops / threads {
                    if live.len() >= 256 || (!live.is_empty() && rng.next_f64() < 0.4) {
                        let i = rng.gen_range(live.len() as u64) as usize;
                        let off = live.swap_remove(i);
                        a.deallocate(off).unwrap();
                    } else {
                        let size = 8 << rng.gen_range(8); // 8..=1024
                        live.push(a.allocate(size as usize).unwrap());
                    }
                }
                for off in live {
                    a.deallocate(off).unwrap();
                }
            });
        }
    });
    t0.elapsed().as_secs_f64()
}

/// `--telemetry-gate`: the CI overhead gate for ISSUE 10. Runs the
/// metall churn workload with the latency sampler fully off
/// (`telemetry_sample: 0`) and at the default rate (1-in-64),
/// interleaved for `--repeats` rounds, and fails when the median
/// default-on time regresses more than `--max-overhead-pct` (default
/// 5%) over sampler-off. Writes `BENCH_telemetry.json` stub-first so CI
/// uploads a meaningful artifact even on a crash mid-gate.
fn telemetry_gate(args: &BenchArgs) -> anyhow::Result<()> {
    let ops = args.get_usize("ops", 120_000);
    let threads = args.get_usize("threads", 4);
    let repeats = args.get_usize("repeats", 5).max(1);
    let bar = args.get_usize("max-overhead-pct", 5) as f64;
    let out = args.get("out").unwrap_or("BENCH_telemetry.json").to_string();
    let stub = JsonObj::new()
        .str("bench", "telemetry_overhead")
        .str("status", "started")
        .int("ops", ops as i64)
        .int("threads", threads as i64)
        .int("repeats", repeats as i64)
        .finish();
    std::fs::write(&out, stub + "\n")?;

    let work = TempDir::new("micro-alloc-tel");
    let run_once = |sample: u32, tag: &str, i: usize| -> anyhow::Result<f64> {
        let dir = work.join(&format!("tel-{tag}-{i}"));
        let opts = ManagerOptions {
            chunk_size: CHUNK,
            file_size: 16 << 20,
            vm_reserve: 32 << 30,
            telemetry_sample: sample,
            ..Default::default()
        };
        let m = MetallManager::create_with(&dir, opts)?;
        let secs = churn(&m, ops, threads, 7 + i as u64);
        m.close()?;
        let _ = std::fs::remove_dir_all(&dir);
        Ok(secs)
    };
    // one unrecorded warmup pair (page-cache + allocator warm paths)
    run_once(0, "warm", 0)?;
    run_once(64, "warm", 1)?;
    let mut off = Vec::with_capacity(repeats);
    let mut on = Vec::with_capacity(repeats);
    for i in 0..repeats {
        // interleaved so slow machine drift hits both arms equally
        off.push(run_once(0, "off", i)?);
        on.push(run_once(64, "on", i)?);
    }
    let median = |v: &[f64]| {
        let mut v = v.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    };
    let (m_off, m_on) = (median(&off), median(&on));
    let overhead_pct = (m_on - m_off) / m_off * 100.0;
    let pass = overhead_pct <= bar;

    let fmt_arr = |v: &[f64]| {
        let items: Vec<String> = v.iter().map(|s| format!("{s:.6}")).collect();
        format!("[{}]", items.join(","))
    };
    let doc = JsonObj::new()
        .str("bench", "telemetry_overhead")
        .str("status", if pass { "ok" } else { "failed" })
        .int("ops", ops as i64)
        .int("threads", threads as i64)
        .int("repeats", repeats as i64)
        .int("sample_rate_on", 64)
        .num("median_off_secs", m_off)
        .num("median_on_secs", m_on)
        .num("overhead_pct", overhead_pct)
        .num("max_overhead_pct", bar)
        .bool("pass", pass)
        .raw("off_secs", &fmt_arr(&off))
        .raw("on_secs", &fmt_arr(&on))
        .finish();
    std::fs::write(&out, doc + "\n")?;

    let mut t = Table::new(&["sampler", "median", "ops/s"]);
    t.row(&["off (0)".into(), human::duration(m_off), human::rate(ops as f64 / m_off)]);
    t.row(&["on (1-in-64)".into(), human::duration(m_on), human::rate(ops as f64 / m_on)]);
    t.print(&format!(
        "telemetry overhead gate: {overhead_pct:+.2}% (bar {bar:.0}%) → {}",
        if pass { "ok" } else { "FAILED" }
    ));
    if !pass {
        anyhow::bail!(
            "telemetry overhead {overhead_pct:.2}% exceeds the {bar:.0}% bar \
             (median off {m_off:.4}s vs default-on {m_on:.4}s)"
        );
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    if args.has("telemetry-gate") {
        return telemetry_gate(&args);
    }
    let ops = args.get_usize("ops", 200_000);
    let threads = args.get_usize_list("threads", &[1, 2, 4, 8]);
    let work = TempDir::new("micro-alloc");

    let mut t = Table::new(&["allocator", "threads", "time", "ops/s"]);
    for &nt in &threads {
        for name in ["metall", "bip", "pmemkind", "ralloc"] {
            let dir = work.join(&format!("{name}-{nt}"));
            let secs = match name {
                "metall" => {
                    let opts = ManagerOptions {
                        chunk_size: CHUNK,
                        file_size: 16 << 20,
                        vm_reserve: 32 << 30,
                        ..Default::default()
                    };
                    let m = MetallManager::create_with(&dir, opts)?;
                    let s = churn(&m, ops, nt, 1);
                    let st = m.stats();
                    record(
                        "micro_alloc",
                        JsonObj::new()
                            .str("allocator", "metall-cache-stats")
                            .int("threads", nt as i64)
                            .int("allocs", st.allocs as i64)
                            .int("cache_hits", st.cache_hits as i64),
                    );
                    m.close()?;
                    s
                }
                "bip" => {
                    let a = BipAllocator::create_with(&dir, seg_opts())?;
                    churn(&a, ops, nt, 1)
                }
                "pmemkind" => {
                    let a = PmemKindAllocator::create_with(
                        &dir,
                        MadvMode::DontNeed,
                        seg_opts(),
                        CHUNK,
                    )?;
                    churn(&a, ops, nt, 1)
                }
                "ralloc" => {
                    let a = RallocLike::create_with(&dir, seg_opts(), CHUNK)?;
                    churn(&a, ops, nt, 1)
                }
                _ => unreachable!(),
            };
            t.row(&[
                name.to_string(),
                nt.to_string(),
                human::duration(secs),
                human::rate(ops as f64 / secs),
            ]);
            record(
                "micro_alloc",
                JsonObj::new()
                    .str("allocator", name)
                    .int("threads", nt as i64)
                    .int("ops", ops as i64)
                    .num("secs", secs)
                    .num("ops_per_sec", ops as f64 / secs),
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
    t.print("alloc/dealloc churn microbenchmark (mixed sizes 8B–1KiB, 40% frees)");
    Ok(())
}

//! Fig 6 — total time for the incremental construction broken down into
//! ingestion time and flush time per (fs, dataset, mode).
//!
//! `cargo bench --bench fig6_breakdown -- [--months 8] [--first-month 20000]`

use metall_rs::bench_util::{record, BenchArgs, Table};
use metall_rs::experiments::fig5::{run_cell, Fig5Params, IoMode};
use metall_rs::util::human;
use metall_rs::util::jsonw::JsonObj;
use metall_rs::util::tmp::TempDir;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let p = Fig5Params {
        months: args.get_usize("months", 8) as u32,
        first_month_edges: args.get_usize("first-month", 20_000),
        ..Default::default()
    };
    let work = TempDir::new("fig6");

    for fs in ["lustre", "vast"] {
        for dataset in ["wiki", "reddit"] {
            let mut t = Table::new(&["mode", "ingest", "flush", "total"]);
            for mode in IoMode::all() {
                let rows = run_cell(fs, dataset, mode, &p, work.path())?;
                let ingest: f64 = rows.iter().map(|r| r.ingest_secs).sum();
                let flush: f64 = rows.iter().map(|r| r.flush_secs).sum();
                t.row(&[
                    mode.name().to_string(),
                    human::duration(ingest),
                    human::duration(flush),
                    human::duration(ingest + flush),
                ]);
                record(
                    "fig6_breakdown",
                    JsonObj::new()
                        .str("fs", fs)
                        .str("dataset", dataset)
                        .str("mode", mode.name())
                        .num("ingest_secs", ingest)
                        .num("flush_secs", flush),
                );
            }
            t.print(&format!("Fig 6 — {dataset} on {fs} (ingest/flush breakdown)"));
        }
    }
    Ok(())
}

//! §3.6 ablation — multi-file backing store: out-of-core sort over a
//! segment split into 1 … N files ("we achieved 4.8X performance
//! improvement by dividing the original array into 512 files").
//!
//! `cargo bench --bench ablation_multifile -- [--mb 256] [--threads 4]`

use metall_rs::bench_util::{record, BenchArgs, Table};
use metall_rs::experiments::ooc;
use metall_rs::util::human;
use metall_rs::util::jsonw::JsonObj;
use metall_rs::util::tmp::TempDir;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let total = args.get_usize("mb", 256) << 20;
    let threads = args.get_usize("threads", 4);
    let work = TempDir::new("ooc-bench");

    println!(
        "out-of-core sort: {} of u64s, {threads} threads, file-count sweep",
        human::bytes(total as u64)
    );
    let mut t = Table::new(&["files", "time", "speedup vs 1 file"]);
    let mut base = None;
    for nfiles in [1usize, 4, 16, 64] {
        let row = ooc::run_one(work.path(), total, nfiles, threads)?;
        let b = *base.get_or_insert(row.secs);
        t.row(&[
            nfiles.to_string(),
            human::duration(row.secs),
            format!("{:.2}x", b / row.secs),
        ]);
        record(
            "ablation_multifile",
            JsonObj::new()
                .int("nfiles", nfiles as i64)
                .num("secs", row.secs)
                .int("bytes", total as i64)
                .int("threads", threads as i64),
        );
    }
    t.print("§3.6 ablation: backing-file count (paper: 4.8x at 512 files / 96 threads)");
    println!("(1-core testbed: expect a smaller effect than the paper's 96-thread NVMe box)");
    Ok(())
}

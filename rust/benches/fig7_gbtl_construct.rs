//! Fig 7 — GBTL graph construction time on the four SNAP stand-ins,
//! base (DRAM) vs GBTL+Metall (persistent store on local disk).
//!
//! `cargo bench --bench fig7_gbtl_construct`

use metall_rs::bench_util::{record, Table};
use metall_rs::experiments::fig7;
use metall_rs::util::human;
use metall_rs::util::jsonw::JsonObj;
use metall_rs::util::tmp::TempDir;

fn main() -> anyhow::Result<()> {
    let work = TempDir::new("fig7");
    let rows = fig7::run(work.path(), |r| println!("  {} done", r.dataset))?;
    let mut t = Table::new(&["dataset", "Base GBTL (DRAM)", "GBTL+Metall (disk)", "ratio"]);
    for r in &rows {
        t.row(&[
            r.dataset.to_string(),
            human::duration(r.base_construct),
            human::duration(r.metall_construct),
            format!("{:.2}x", r.metall_construct / r.base_construct),
        ]);
        record(
            "fig7_gbtl_construct",
            JsonObj::new()
                .str("dataset", r.dataset)
                .num("base_secs", r.base_construct)
                .num("metall_secs", r.metall_construct),
        );
    }
    t.print("Fig 7 — GBTL graph construction time (paper: Metall ≈ 2x slower, one-time cost)");
    Ok(())
}

//! Thread- and shard-scaling benchmark for the small-allocation fast
//! path (§4.5 concurrency design + the sharded bin directory).
//!
//! Measures aggregate alloc/dealloc throughput of one shared
//! `MetallManager` over a (threads × shards) matrix of mixed small size
//! classes, and reports the speedup relative to single-threaded as well
//! as the sharding delta at the highest thread count. The acceptance bar
//! for the sharded directory is ≥ 1.5× throughput at 8 threads / 4
//! shards over 8 threads / 1 shard.
//!
//! Results go to the human table, to `bench_results/concurrent_alloc.jsonl`
//! (append-only history), and to `BENCH_concurrent_alloc.json` at the
//! repo root — one machine-readable document per run so the perf
//! trajectory is tracked across PRs.
//!
//! `cargo bench --bench concurrent_alloc -- [--ops 400000]
//!  [--threads 1,2,4,8] [--shards 1,2,4] [--repeats 3] [--live 192]`

use metall_rs::alloc::{ManagerOptions, MetallHandle, MetallManager, ShardStatsSnapshot};
use metall_rs::bench_util::{record, BenchArgs, Table};
use metall_rs::util::human;
use metall_rs::util::jsonw::JsonObj;
use metall_rs::util::rng::Xoshiro256ss;
use metall_rs::util::tmp::TempDir;

const CHUNK: usize = 1 << 20;

/// Mixed small-class churn: every thread keeps a bounded live window and
/// allocates/frees objects spanning eight size classes (8 B – 1 KiB).
/// Returns elapsed seconds for `ops` total operations across `threads`.
fn churn(h: &MetallHandle, ops: usize, threads: usize, live_cap: usize, seed: u64) -> f64 {
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for t in 0..threads {
            let h = h.clone();
            s.spawn(move || {
                let mut rng = Xoshiro256ss::new(seed + t as u64);
                let mut live: Vec<u64> = Vec::with_capacity(live_cap);
                for _ in 0..ops / threads {
                    if live.len() >= live_cap || (!live.is_empty() && rng.next_f64() < 0.4)
                    {
                        let i = rng.gen_range(live.len() as u64) as usize;
                        let off = live.swap_remove(i);
                        h.deallocate(off).unwrap();
                    } else {
                        let size = 8usize << rng.gen_range(8); // 8..=1024
                        live.push(h.allocate(size).unwrap());
                    }
                }
                for off in live {
                    h.deallocate(off).unwrap();
                }
            });
        }
    });
    t0.elapsed().as_secs_f64()
}

struct Cell {
    threads: usize,
    shards: usize,
    secs: f64,
    rate: f64,
    speedup_vs_1t: f64,
    fast_claims: u64,
    cache_hits: u64,
    fresh_chunks: u64,
    remote_frees: u64,
    exclusive_acquires: u64,
}

fn shard_sum(ss: &[ShardStatsSnapshot], f: impl Fn(&ShardStatsSnapshot) -> u64) -> u64 {
    ss.iter().map(f).sum()
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let ops = args.get_usize("ops", 400_000);
    let threads = args.get_usize_list("threads", &[1, 2, 4, 8]);
    let shard_counts = args.get_usize_list("shards", &[1, 2, 4]);
    let repeats = args.get_usize("repeats", 3);
    let live_cap = args.get_usize("live", 192);
    let work = TempDir::new("concurrent-alloc");

    let mut t = Table::new(&[
        "shards", "threads", "time", "agg ops/s", "speedup", "fast claims", "remote frees",
        "excl locks",
    ]);
    let mut cells: Vec<Cell> = Vec::new();
    for &ns in &shard_counts {
        let mut base_rate = 0.0f64;
        for &nt in &threads {
            // best-of-N to shed scheduler noise; fresh store per run so
            // every cell sees identical initial state. The reported
            // counters come from the same repeat as the reported time.
            let mut best = f64::INFINITY;
            let mut stats = Default::default();
            let mut per_shard: Vec<ShardStatsSnapshot> = Vec::new();
            for rep in 0..repeats.max(1) {
                let dir = work.join(&format!("s{ns}-t{nt}-r{rep}"));
                let opts = ManagerOptions {
                    chunk_size: CHUNK,
                    file_size: 16 << 20,
                    vm_reserve: 32 << 30,
                    shards: ns,
                    ..Default::default()
                };
                let h = MetallHandle::new(MetallManager::create_with(&dir, opts)?);
                let secs = churn(&h, ops, nt, live_cap, 1);
                let (tot, ss) = h.stats_with_shards();
                h.try_close().map_err(|e| anyhow::anyhow!("{e}"))?;
                let _ = std::fs::remove_dir_all(&dir);
                if secs < best {
                    best = secs;
                    stats = tot;
                    per_shard = ss;
                }
            }
            let rate = ops as f64 / best;
            if nt == threads[0] {
                base_rate = rate;
            }
            let speedup = rate / base_rate;
            let remote_frees = shard_sum(&per_shard, |s| s.remote_frees);
            let excl = shard_sum(&per_shard, |s| s.exclusive_acquires);
            t.row(&[
                ns.to_string(),
                nt.to_string(),
                human::duration(best),
                human::rate(rate),
                format!("{speedup:.2}x"),
                stats.fast_claims.to_string(),
                remote_frees.to_string(),
                excl.to_string(),
            ]);
            record(
                "concurrent_alloc",
                JsonObj::new()
                    .str("bench", "mixed-small-churn")
                    .int("shards", ns as i64)
                    .int("threads", nt as i64)
                    .int("ops", ops as i64)
                    .num("secs", best)
                    .num("ops_per_sec", rate)
                    .num("speedup_vs_1t", speedup)
                    .int("fast_claims", stats.fast_claims as i64)
                    .int("cache_hits", stats.cache_hits as i64)
                    .int("fresh_chunks", stats.fresh_chunks as i64)
                    .int("remote_frees", remote_frees as i64)
                    .int("exclusive_acquires", excl as i64),
            );
            cells.push(Cell {
                threads: nt,
                shards: ns,
                secs: best,
                rate,
                speedup_vs_1t: speedup,
                fast_claims: stats.fast_claims,
                cache_hits: stats.cache_hits,
                fresh_chunks: stats.fresh_chunks,
                remote_frees,
                exclusive_acquires: excl,
            });
        }
    }
    t.print("thread × shard scaling: shared manager, mixed small classes (8B–1KiB, 40% frees)");

    // sharding delta at the highest thread count: max shards vs 1 shard
    let max_t = threads.iter().copied().max().unwrap_or(1);
    let rate_of = |ns: usize| {
        cells
            .iter()
            .find(|c| c.threads == max_t && c.shards == ns)
            .map(|c| c.rate)
    };
    let max_s = shard_counts.iter().copied().max().unwrap_or(1);
    let shard_speedup = match (rate_of(1), rate_of(max_s)) {
        (Some(r1), Some(rs)) if r1 > 0.0 => Some(rs / r1),
        _ => None,
    };
    if let Some(sp) = shard_speedup {
        println!(
            "\nsharding delta at {max_t} threads: {max_s} shards vs 1 shard = {sp:.2}x \
             (target ≥ 1.5x for the sharded bin directory)"
        );
    }

    // machine-readable summary at the repo root (one document per run,
    // overwritten: the perf trajectory across PRs lives in git history)
    let mut rows = String::from("[");
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            rows.push(',');
        }
        rows.push_str(
            &JsonObj::new()
                .int("threads", c.threads as i64)
                .int("shards", c.shards as i64)
                .num("secs", c.secs)
                .num("ops_per_sec", c.rate)
                .num("speedup_vs_1t", c.speedup_vs_1t)
                .int("fast_claims", c.fast_claims as i64)
                .int("cache_hits", c.cache_hits as i64)
                .int("fresh_chunks", c.fresh_chunks as i64)
                .int("remote_frees", c.remote_frees as i64)
                .int("exclusive_acquires", c.exclusive_acquires as i64)
                .finish(),
        );
    }
    rows.push(']');
    let mut doc = JsonObj::new()
        .str("bench", "concurrent_alloc")
        .str("workload", "mixed-small-churn 8B-1KiB, 40% frees")
        .int("ops", ops as i64)
        .int("repeats", repeats as i64)
        .int("live_cap", live_cap as i64)
        .raw("results", &rows);
    if let Some(sp) = shard_speedup {
        doc = doc
            .int("shard_speedup_threads", max_t as i64)
            .int("shard_speedup_shards", max_s as i64)
            .num("shard_speedup", sp);
    }
    std::fs::write("BENCH_concurrent_alloc.json", doc.finish() + "\n")?;
    println!("wrote BENCH_concurrent_alloc.json");
    Ok(())
}

//! Thread-scaling benchmark for the lock-free small-allocation fast path
//! (§4.5 concurrency design + the atomic-bitset claim path).
//!
//! Measures aggregate alloc/dealloc throughput of one shared
//! `MetallManager` at 1/2/4/8 threads over mixed small size classes, and
//! reports the speedup relative to single-threaded. The acceptance bar
//! for the fast path is ≥ 2x aggregate throughput at 8 threads.
//!
//! `cargo bench --bench concurrent_alloc -- [--ops 400000]
//!  [--threads 1,2,4,8] [--repeats 3] [--live 192]`

use metall_rs::alloc::{ManagerOptions, MetallHandle, MetallManager};
use metall_rs::bench_util::{record, BenchArgs, Table};
use metall_rs::util::human;
use metall_rs::util::jsonw::JsonObj;
use metall_rs::util::rng::Xoshiro256ss;
use metall_rs::util::tmp::TempDir;

const CHUNK: usize = 1 << 20;

/// Mixed small-class churn: every thread keeps a bounded live window and
/// allocates/frees objects spanning eight size classes (8 B – 1 KiB).
/// Returns elapsed seconds for `ops` total operations across `threads`.
fn churn(h: &MetallHandle, ops: usize, threads: usize, live_cap: usize, seed: u64) -> f64 {
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for t in 0..threads {
            let h = h.clone();
            s.spawn(move || {
                let mut rng = Xoshiro256ss::new(seed + t as u64);
                let mut live: Vec<u64> = Vec::with_capacity(live_cap);
                for _ in 0..ops / threads {
                    if live.len() >= live_cap || (!live.is_empty() && rng.next_f64() < 0.4)
                    {
                        let i = rng.gen_range(live.len() as u64) as usize;
                        let off = live.swap_remove(i);
                        h.deallocate(off).unwrap();
                    } else {
                        let size = 8usize << rng.gen_range(8); // 8..=1024
                        live.push(h.allocate(size).unwrap());
                    }
                }
                for off in live {
                    h.deallocate(off).unwrap();
                }
            });
        }
    });
    t0.elapsed().as_secs_f64()
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let ops = args.get_usize("ops", 400_000);
    let threads = args.get_usize_list("threads", &[1, 2, 4, 8]);
    let repeats = args.get_usize("repeats", 3);
    let live_cap = args.get_usize("live", 192);
    let work = TempDir::new("concurrent-alloc");

    let mut t = Table::new(&[
        "threads", "time", "agg ops/s", "speedup", "fast claims", "cache hits",
    ]);
    let mut base_rate = 0.0f64;
    let mut rate_at = Vec::new();
    for &nt in &threads {
        // best-of-N to shed scheduler noise; fresh store per run so every
        // thread count sees identical initial state
        let mut best = f64::INFINITY;
        let mut stats = Default::default();
        for rep in 0..repeats.max(1) {
            let dir = work.join(&format!("t{nt}-r{rep}"));
            let opts = ManagerOptions {
                chunk_size: CHUNK,
                file_size: 16 << 20,
                vm_reserve: 32 << 30,
                ..Default::default()
            };
            let h = MetallHandle::new(MetallManager::create_with(&dir, opts)?);
            let secs = churn(&h, ops, nt, live_cap, 1);
            stats = h.stats();
            h.try_close().map_err(|e| anyhow::anyhow!("{e}"))?;
            let _ = std::fs::remove_dir_all(&dir);
            best = best.min(secs);
        }
        let rate = ops as f64 / best;
        if nt == threads[0] {
            base_rate = rate;
        }
        let speedup = rate / base_rate;
        rate_at.push((nt, rate, speedup));
        t.row(&[
            nt.to_string(),
            human::duration(best),
            human::rate(rate),
            format!("{speedup:.2}x"),
            stats.fast_claims.to_string(),
            stats.cache_hits.to_string(),
        ]);
        record(
            "concurrent_alloc",
            JsonObj::new()
                .str("bench", "mixed-small-churn")
                .int("threads", nt as i64)
                .int("ops", ops as i64)
                .num("secs", best)
                .num("ops_per_sec", rate)
                .num("speedup_vs_1t", speedup)
                .int("fast_claims", stats.fast_claims as i64)
                .int("cache_hits", stats.cache_hits as i64)
                .int("fresh_chunks", stats.fresh_chunks as i64),
        );
    }
    t.print("thread-scaling: shared manager, mixed small classes (8B–1KiB, 40% frees)");
    if let (Some(&(_, _, _)), Some(&(nt_max, _, sp_max))) =
        (rate_at.first(), rate_at.last())
    {
        println!(
            "\naggregate speedup at {nt_max} threads: {sp_max:.2}x \
             (target ≥ 2x for the lock-free fast path)"
        );
    }
    Ok(())
}

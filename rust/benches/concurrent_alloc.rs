//! Thread-, shard-, and NUMA-scaling benchmark for the small-allocation
//! fast path (§4.5 concurrency design + the sharded bin directory + the
//! topology-aware placement layer).
//!
//! Measures aggregate alloc/dealloc throughput of one shared
//! `MetallManager` over a (nodes × shards × threads) matrix of mixed
//! small size classes. The `nodes` dimension injects fake topologies
//! (`Topology::fake`) with worker vcpus pinned, so the NUMA routing and
//! first-touch paths are exercised — and their placement measured via
//! `placement_report()` — even on single-node machines. `nodes = 1` runs
//! the machine topology unpinned, directly comparable to earlier PRs.
//!
//! Results go to the human table, to `bench_results/concurrent_alloc.jsonl`
//! (append-only history), and to `BENCH_concurrent_alloc.json` at the
//! repo root. That file is written twice: a `"status": "started"` stub
//! before the first measurement and the full document at the end — so
//! every run leaves a machine-readable trace even if it is interrupted,
//! on any machine shape (1 shard / 1 node included).
//!
//! `cargo bench --bench concurrent_alloc -- [--ops 400000]
//!  [--threads 1,2,4,8] [--shards 1,2,4] [--nodes 1,2] [--repeats 3]
//!  [--live 192]`

use metall_rs::alloc::{
    pin_thread_vcpu, ManagerOptions, MetallHandle, MetallManager, ShardStatsSnapshot,
};
use metall_rs::bench_util::{record, BenchArgs, Table};
use metall_rs::numa::Topology;
use metall_rs::util::human;
use metall_rs::util::jsonw::JsonObj;
use metall_rs::util::rng::Xoshiro256ss;
use metall_rs::util::tmp::TempDir;

const CHUNK: usize = 1 << 20;
const OUT: &str = "BENCH_concurrent_alloc.json";

/// Mixed small-class churn: every thread keeps a bounded live window and
/// allocates/frees objects spanning eight size classes (8 B – 1 KiB).
/// With `pin`, worker `t` is pinned to vcpu `t` (the numa dimension needs
/// deterministic thread→node assignment). Returns elapsed seconds for
/// `ops` total operations across `threads`.
fn churn(
    h: &MetallHandle,
    ops: usize,
    threads: usize,
    live_cap: usize,
    seed: u64,
    pin: bool,
) -> f64 {
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for t in 0..threads {
            let h = h.clone();
            s.spawn(move || {
                if pin {
                    pin_thread_vcpu(Some(t));
                }
                let mut rng = Xoshiro256ss::new(seed + t as u64);
                let mut live: Vec<u64> = Vec::with_capacity(live_cap);
                for _ in 0..ops / threads {
                    if live.len() >= live_cap || (!live.is_empty() && rng.next_f64() < 0.4)
                    {
                        let i = rng.gen_range(live.len() as u64) as usize;
                        let off = live.swap_remove(i);
                        h.deallocate(off).unwrap();
                    } else {
                        let size = 8usize << rng.gen_range(8); // 8..=1024
                        live.push(h.allocate(size).unwrap());
                    }
                }
                for off in live {
                    h.deallocate(off).unwrap();
                }
            });
        }
    });
    t0.elapsed().as_secs_f64()
}

/// Non-timed placement probe: allocate a wave of live objects from every
/// worker vcpu, read `placement_report()`, free the wave. Returns
/// (node-local pages, attributed small-chunk pages).
fn placement_probe(h: &MetallHandle, threads: usize) -> (u64, u64) {
    let mut offs = Vec::new();
    for t in 0..threads {
        pin_thread_vcpu(Some(t));
        for _ in 0..64 {
            offs.push(h.allocate(256).unwrap());
        }
    }
    pin_thread_vcpu(None);
    let r = h.placement_report();
    for off in offs {
        h.deallocate(off).unwrap();
    }
    let local: u64 = r.per_shard.iter().map(|s| s.node_local_pages).sum();
    let pages: u64 = r.per_shard.iter().map(|s| s.pages).sum();
    (local, pages)
}

struct Cell {
    nodes: usize,
    threads: usize,
    shards: usize,
    secs: f64,
    rate: f64,
    speedup_vs_1t: f64,
    fast_claims: u64,
    cache_hits: u64,
    fresh_chunks: u64,
    remote_frees: u64,
    exclusive_acquires: u64,
    node_local_pages: u64,
    placement_pages: u64,
}

fn shard_sum(ss: &[ShardStatsSnapshot], f: impl Fn(&ShardStatsSnapshot) -> u64) -> u64 {
    ss.iter().map(f).sum()
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let ops = args.get_usize("ops", 400_000);
    let threads = args.get_usize_list("threads", &[1, 2, 4, 8]);
    let shard_counts = args.get_usize_list("shards", &[1, 2, 4]);
    let node_counts = args.get_usize_list("nodes", &[1, 2]);
    let repeats = args.get_usize("repeats", 3);
    let live_cap = args.get_usize("live", 192);
    let work = TempDir::new("concurrent-alloc");

    // the trajectory file must exist whatever happens after this point
    let stub = JsonObj::new()
        .str("bench", "concurrent_alloc")
        .str("status", "started")
        .raw("results", "[]")
        .finish();
    std::fs::write(OUT, stub + "\n")?;

    let mut t = Table::new(&[
        "nodes", "shards", "threads", "time", "agg ops/s", "speedup", "fast claims",
        "remote frees", "excl locks", "node-local",
    ]);
    let mut cells: Vec<Cell> = Vec::new();
    let max_threads = threads.iter().copied().max().unwrap_or(1);
    for &nn in &node_counts {
        // nodes = 1: machine topology, unpinned (comparable to earlier
        // PRs); nodes > 1: injected fake topology with pinned workers so
        // every node's shards see traffic
        let fake = (nn > 1).then(|| Topology::fake(&vec![max_threads.div_ceil(nn); nn]));
        for &ns in &shard_counts {
            let mut base_rate = 0.0f64;
            for &nt in &threads {
                // best-of-N to shed scheduler noise; fresh store per run so
                // every cell sees identical initial state. The reported
                // counters come from the same repeat as the reported time.
                let mut best = f64::INFINITY;
                let mut stats = Default::default();
                let mut per_shard: Vec<ShardStatsSnapshot> = Vec::new();
                let mut placement = (0u64, 0u64);
                for rep in 0..repeats.max(1) {
                    let dir = work.join(&format!("n{nn}-s{ns}-t{nt}-r{rep}"));
                    let opts = ManagerOptions {
                        chunk_size: CHUNK,
                        file_size: 16 << 20,
                        vm_reserve: 32 << 30,
                        shards: ns,
                        topology: fake.clone(),
                        ..Default::default()
                    };
                    let h = MetallHandle::new(MetallManager::create_with(&dir, opts)?);
                    let secs = churn(&h, ops, nt, live_cap, 1, fake.is_some());
                    // counters snapshot first: the probe's own allocations
                    // must not pollute the churn counters the trajectory
                    // compares across PRs
                    let (tot, ss) = h.stats_with_shards();
                    let probe = placement_probe(&h, nt);
                    h.try_close().map_err(|e| anyhow::anyhow!("{e}"))?;
                    let _ = std::fs::remove_dir_all(&dir);
                    if secs < best {
                        best = secs;
                        stats = tot;
                        per_shard = ss;
                        placement = probe;
                    }
                }
                let rate = ops as f64 / best;
                if nt == threads[0] {
                    base_rate = rate;
                }
                let speedup = rate / base_rate;
                let remote_frees = shard_sum(&per_shard, |s| s.remote_frees);
                let excl = shard_sum(&per_shard, |s| s.exclusive_acquires);
                let (local, pages) = placement;
                let local_str = if pages > 0 {
                    format!("{:.0}%", 100.0 * local as f64 / pages as f64)
                } else {
                    "-".to_string()
                };
                t.row(&[
                    nn.to_string(),
                    ns.to_string(),
                    nt.to_string(),
                    human::duration(best),
                    human::rate(rate),
                    format!("{speedup:.2}x"),
                    stats.fast_claims.to_string(),
                    remote_frees.to_string(),
                    excl.to_string(),
                    local_str,
                ]);
                record(
                    "concurrent_alloc",
                    JsonObj::new()
                        .str("bench", "mixed-small-churn")
                        .int("nodes", nn as i64)
                        .int("shards", ns as i64)
                        .int("threads", nt as i64)
                        .int("ops", ops as i64)
                        .num("secs", best)
                        .num("ops_per_sec", rate)
                        .num("speedup_vs_1t", speedup)
                        .int("fast_claims", stats.fast_claims as i64)
                        .int("cache_hits", stats.cache_hits as i64)
                        .int("fresh_chunks", stats.fresh_chunks as i64)
                        .int("remote_frees", remote_frees as i64)
                        .int("exclusive_acquires", excl as i64)
                        .int("node_local_pages", local as i64)
                        .int("placement_pages", pages as i64),
                );
                cells.push(Cell {
                    nodes: nn,
                    threads: nt,
                    shards: ns,
                    secs: best,
                    rate,
                    speedup_vs_1t: speedup,
                    fast_claims: stats.fast_claims,
                    cache_hits: stats.cache_hits,
                    fresh_chunks: stats.fresh_chunks,
                    remote_frees,
                    exclusive_acquires: excl,
                    node_local_pages: local,
                    placement_pages: pages,
                });
            }
        }
    }
    t.print(
        "node × shard × thread scaling: shared manager, mixed small classes (8B–1KiB, 40% frees)",
    );

    // sharding delta at the highest thread count on the machine topology:
    // max shards vs 1 shard
    let max_t = max_threads;
    let rate_of = |ns: usize| {
        cells
            .iter()
            .find(|c| c.nodes == 1 && c.threads == max_t && c.shards == ns)
            .map(|c| c.rate)
    };
    let max_s = shard_counts.iter().copied().max().unwrap_or(1);
    let shard_speedup = match (rate_of(1), rate_of(max_s)) {
        (Some(r1), Some(rs)) if r1 > 0.0 => Some(rs / r1),
        _ => None,
    };
    if let Some(sp) = shard_speedup {
        println!(
            "\nsharding delta at {max_t} threads: {max_s} shards vs 1 shard = {sp:.2}x \
             (target ≥ 1.5x for the sharded bin directory)"
        );
    }
    // placement bar under the largest fake topology: ≥ 95% node-local
    let numa_local = cells
        .iter()
        .filter(|c| c.nodes > 1 && c.placement_pages > 0)
        .map(|c| c.node_local_pages as f64 / c.placement_pages as f64)
        .fold(f64::INFINITY, f64::min);
    if numa_local.is_finite() {
        println!(
            "numa placement: worst node-local share across fake-topology cells = {:.1}% \
             (target ≥ 95%)",
            100.0 * numa_local
        );
    }

    // machine-readable summary at the repo root (one document per run,
    // overwritten: the perf trajectory across PRs lives in git history)
    let mut rows = String::from("[");
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            rows.push(',');
        }
        rows.push_str(
            &JsonObj::new()
                .int("nodes", c.nodes as i64)
                .int("threads", c.threads as i64)
                .int("shards", c.shards as i64)
                .num("secs", c.secs)
                .num("ops_per_sec", c.rate)
                .num("speedup_vs_1t", c.speedup_vs_1t)
                .int("fast_claims", c.fast_claims as i64)
                .int("cache_hits", c.cache_hits as i64)
                .int("fresh_chunks", c.fresh_chunks as i64)
                .int("remote_frees", c.remote_frees as i64)
                .int("exclusive_acquires", c.exclusive_acquires as i64)
                .int("node_local_pages", c.node_local_pages as i64)
                .int("placement_pages", c.placement_pages as i64)
                .finish(),
        );
    }
    rows.push(']');
    let mut doc = JsonObj::new()
        .str("bench", "concurrent_alloc")
        .str("status", "complete")
        .str("workload", "mixed-small-churn 8B-1KiB, 40% frees")
        .int("ops", ops as i64)
        .int("repeats", repeats as i64)
        .int("live_cap", live_cap as i64)
        .raw("results", &rows);
    if let Some(sp) = shard_speedup {
        doc = doc
            .int("shard_speedup_threads", max_t as i64)
            .int("shard_speedup_shards", max_s as i64)
            .num("shard_speedup", sp);
    }
    if numa_local.is_finite() {
        doc = doc.num("numa_worst_node_local_share", numa_local);
    }
    std::fs::write(OUT, doc.finish() + "\n")?;
    println!("wrote {OUT}");
    Ok(())
}

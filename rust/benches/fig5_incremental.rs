//! Fig 5 — cumulative execution time per month of incrementally
//! constructing the Wikipedia-like and Reddit-like graphs on simulated
//! Lustre and VAST, for direct-mmap / staging-mmap / bs-mmap — plus the
//! background-engine comparison (serial depth-1 vs pipelined depth-2
//! month-boundary flushes on the same simulated backends).
//!
//! `cargo bench --bench fig5_incremental -- [--months 8] [--first-month 20000]`

use metall_rs::bench_util::{record, BenchArgs, Table};
use metall_rs::experiments::fig5::{run_bg_cell, run_cell, Fig5Params, IoMode};
use metall_rs::telemetry::export::OpLatency;
use metall_rs::util::human;
use metall_rs::util::jsonw::JsonObj;
use metall_rs::util::tmp::TempDir;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let p = Fig5Params {
        months: args.get_usize("months", 8) as u32,
        first_month_edges: args.get_usize("first-month", 20_000),
        ..Default::default()
    };
    let work = TempDir::new("fig5");

    for fs in ["lustre", "vast"] {
        for dataset in ["wiki", "reddit"] {
            let mut t = Table::new(&["month", "direct-mmap", "staging-mmap", "bs-mmap"]);
            let mut cells = Vec::new();
            for mode in IoMode::all() {
                cells.push(run_cell(fs, dataset, mode, &p, work.path())?);
            }
            let mut cum = [0.0f64; 3];
            for m in 0..p.months as usize {
                let mut rowvals = vec![format!("{m}")];
                for (i, cell) in cells.iter().enumerate() {
                    cum[i] += cell[m].ingest_secs + cell[m].flush_secs;
                    rowvals.push(human::duration(cum[i]));
                    record(
                        "fig5_incremental",
                        JsonObj::new()
                            .str("fs", fs)
                            .str("dataset", dataset)
                            .str("mode", cell[m].mode)
                            .int("month", m as i64)
                            .int("edges", cell[m].edges as i64)
                            .num("ingest_secs", cell[m].ingest_secs)
                            .num("flush_secs", cell[m].flush_secs)
                            .num("cumulative_secs", cum[i]),
                    );
                }
                t.row(&rowvals);
            }
            t.print(&format!("Fig 5 — {dataset} on {fs} (cumulative, simulated time)"));
            // paper shape notes
            let (d, s, b) = (cum[0], cum[1], cum[2]);
            let winner = if fs == "lustre" { "staging-mmap" } else { "bs-mmap" };
            println!(
                "  totals: direct {} | staging {} | bs {}   (paper winner on {fs}: {winner})",
                human::duration(d),
                human::duration(s),
                human::duration(b)
            );
        }
    }

    // Background-engine comparison: the same incremental shape with the
    // flush on the sync engine — strictly serial vs epoch-pipelined.
    for fs in ["lustre", "vast"] {
        let mut t = Table::new(&["month", "bg-serial flush", "bg-pipelined flush"]);
        let (serial, _) = run_bg_cell(fs, "wiki", false, &p, work.path())?;
        let (piped, piped_lat) = run_bg_cell(fs, "wiki", true, &p, work.path())?;
        let (mut cs, mut cp) = (0.0f64, 0.0f64);
        for m in 0..p.months as usize {
            cs += serial[m].flush_secs;
            cp += piped[m].flush_secs;
            t.row(&[format!("{m}"), human::duration(cs), human::duration(cp)]);
            for cell in [&serial, &piped] {
                record(
                    "fig5_incremental",
                    JsonObj::new()
                        .str("fs", fs)
                        .str("dataset", "wiki")
                        .str("mode", cell[m].mode)
                        .int("month", m as i64)
                        .int("edges", cell[m].edges as i64)
                        .num("ingest_secs", cell[m].ingest_secs)
                        .num("flush_secs", cell[m].flush_secs),
                );
            }
        }
        t.print(&format!(
            "Fig 5 — wiki on {fs}, background engine (cumulative flush stall)"
        ));
        println!(
            "  totals: bg-serial {} | bg-pipelined {} = {:.2}x",
            human::duration(cs),
            human::duration(cp),
            cp / cs.max(1e-9)
        );
        // tail latency of the pipelined engine's epoch phases, from the
        // always-on telemetry histograms
        let mut lt = Table::new(&["op", "samples", "p50", "p99", "p999"]);
        for (op, snap) in &piped_lat {
            if snap.count == 0 {
                continue;
            }
            let l = OpLatency::from_snapshot(*op, snap);
            lt.row(&[
                l.op.to_string(),
                l.count.to_string(),
                human::duration(l.p50 as f64 / 1e9),
                human::duration(l.p99 as f64 / 1e9),
                human::duration(l.p999 as f64 / 1e9),
            ]);
            record(
                "fig5_incremental",
                JsonObj::new()
                    .str("bench", "fig5-bg-quantiles")
                    .str("fs", fs)
                    .str("op", l.op)
                    .int("count", l.count as i64)
                    .int("p50_ns", l.p50 as i64)
                    .int("p99_ns", l.p99 as i64)
                    .int("p999_ns", l.p999 as i64),
            );
        }
        lt.print(&format!("Fig 5 — wiki on {fs}, bg-pipelined per-op latency quantiles"));
    }
    Ok(())
}

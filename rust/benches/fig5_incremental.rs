//! Fig 5 — cumulative execution time per month of incrementally
//! constructing the Wikipedia-like and Reddit-like graphs on simulated
//! Lustre and VAST, for direct-mmap / staging-mmap / bs-mmap.
//!
//! `cargo bench --bench fig5_incremental -- [--months 8] [--first-month 20000]`

use metall_rs::bench_util::{record, BenchArgs, Table};
use metall_rs::experiments::fig5::{run_cell, Fig5Params, IoMode};
use metall_rs::util::human;
use metall_rs::util::jsonw::JsonObj;
use metall_rs::util::tmp::TempDir;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let p = Fig5Params {
        months: args.get_usize("months", 8) as u32,
        first_month_edges: args.get_usize("first-month", 20_000),
        ..Default::default()
    };
    let work = TempDir::new("fig5");

    for fs in ["lustre", "vast"] {
        for dataset in ["wiki", "reddit"] {
            let mut t = Table::new(&["month", "direct-mmap", "staging-mmap", "bs-mmap"]);
            let mut cells = Vec::new();
            for mode in IoMode::all() {
                cells.push(run_cell(fs, dataset, mode, &p, work.path())?);
            }
            let mut cum = [0.0f64; 3];
            for m in 0..p.months as usize {
                let mut rowvals = vec![format!("{m}")];
                for (i, cell) in cells.iter().enumerate() {
                    cum[i] += cell[m].ingest_secs + cell[m].flush_secs;
                    rowvals.push(human::duration(cum[i]));
                    record(
                        "fig5_incremental",
                        JsonObj::new()
                            .str("fs", fs)
                            .str("dataset", dataset)
                            .str("mode", cell[m].mode)
                            .int("month", m as i64)
                            .int("edges", cell[m].edges as i64)
                            .num("ingest_secs", cell[m].ingest_secs)
                            .num("flush_secs", cell[m].flush_secs)
                            .num("cumulative_secs", cum[i]),
                    );
                }
                t.row(&rowvals);
            }
            t.print(&format!("Fig 5 — {dataset} on {fs} (cumulative, simulated time)"));
            // paper shape notes
            let (d, s, b) = (cum[0], cum[1], cum[2]);
            let winner = if fs == "lustre" { "staging-mmap" } else { "bs-mmap" };
            println!(
                "  totals: direct {} | staging {} | bs {}   (paper winner on {fs}: {winner})",
                human::duration(d),
                human::duration(s),
                human::duration(b)
            );
        }
    }
    Ok(())
}

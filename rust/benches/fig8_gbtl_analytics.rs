//! Fig 8 — GBTL analytics time: base must reconstruct the graph before
//! analyzing; GBTL+Metall reattaches the pre-built persistent graph
//! (paper: "boosts up the analytics time up to 3.5X").
//!
//! `cargo bench --bench fig8_gbtl_analytics`

use metall_rs::bench_util::{record, Table};
use metall_rs::experiments::fig7;
use metall_rs::util::human;
use metall_rs::util::jsonw::JsonObj;
use metall_rs::util::tmp::TempDir;

fn main() -> anyhow::Result<()> {
    let work = TempDir::new("fig8");
    let rows = fig7::run(work.path(), |r| println!("  {} done", r.dataset))?;

    let mut ta = Table::new(&["dataset", "Base (construct+BFS)", "Metall (reattach+BFS)", "speedup"]);
    let mut tb = Table::new(&["dataset", "Base (construct+PR)", "Metall (reattach+PR)", "speedup"]);
    for r in &rows {
        ta.row(&[
            r.dataset.to_string(),
            human::duration(r.base_bfs_total),
            human::duration(r.metall_bfs_total),
            format!("{:.1}x", r.base_bfs_total / r.metall_bfs_total),
        ]);
        tb.row(&[
            r.dataset.to_string(),
            human::duration(r.base_pr_total),
            human::duration(r.metall_pr_total),
            format!("{:.1}x", r.base_pr_total / r.metall_pr_total),
        ]);
        record(
            "fig8_gbtl_analytics",
            JsonObj::new()
                .str("dataset", r.dataset)
                .num("base_bfs_secs", r.base_bfs_total)
                .num("metall_bfs_secs", r.metall_bfs_total)
                .num("base_pr_secs", r.base_pr_total)
                .num("metall_pr_secs", r.metall_pr_total),
        );
    }
    ta.print("Fig 8a — BFS analytics time");
    tb.print("Fig 8b — PageRank analytics time");
    println!("(paper: up to 3.5x from avoiding reconstruction)");
    Ok(())
}

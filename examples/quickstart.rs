//! Quickstart: the paper's Code 2 / Code 3 flow in metall-rs.
//!
//! Creates a datastore, persists an int, a vector, and a small graph,
//! closes — then reattaches everything without any reconstruction.
//!
//! Run: `cargo run --release --example quickstart`

use metall_rs::alloc::MetallManager;
use metall_rs::containers::{BankedAdjacency, PVec};

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join(format!("metallrs-quickstart-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // ---- first process lifetime: create + populate (paper Code 2/3) ----
    {
        let mgr = MetallManager::create(&dir)?;

        // an int object, constructed under the name "data"
        mgr.construct::<u64>("data", 10)?;

        // an STL-style vector (paper Code 3)
        let vec = PVec::<f64>::create(&mgr)?;
        for i in 0..1000 {
            vec.push(&mgr, f64::from(i) * 0.5)?;
        }
        mgr.construct::<u64>("vec", vec.offset())?;

        // a small graph in a banked adjacency list (paper §6.1)
        let graph = BankedAdjacency::create(&mgr, 16)?;
        for (s, d) in [(0u64, 1u64), (0, 2), (1, 3), (2, 3)] {
            graph.insert_edge(&mgr, s, d)?;
            graph.insert_edge(&mgr, d, s)?;
        }
        mgr.construct::<u64>("graph", graph.offset())?;

        mgr.close()?; // snapshot-consistency point (§3.3)
        println!("populated and closed datastore at {}", dir.display());
    }

    // ---- second process lifetime: reattach, no reconstruction ----
    {
        let mgr = MetallManager::open(&dir)?;

        let off = mgr.find::<u64>("data")?.expect("data");
        println!("data = {}", mgr.read::<u64>(off));
        assert_eq!(mgr.read::<u64>(off), 10);

        let vec = PVec::<f64>::from_offset(mgr.read(mgr.find::<u64>("vec")?.unwrap()));
        println!("vec: len={} vec[500]={}", vec.len(&mgr), vec.get(&mgr, 500));
        assert_eq!(vec.get(&mgr, 500), 250.0);

        let graph = BankedAdjacency::open(&mgr, mgr.read(mgr.find::<u64>("graph")?.unwrap()));
        println!(
            "graph: {} vertices, {} directed edges, neighbors(0) = {:?}",
            graph.num_vertices(&mgr),
            graph.num_edges(&mgr),
            graph.neighbors(&mgr, 0)
        );
        assert_eq!(graph.num_edges(&mgr), 8);

        // snapshot the store (reflink where supported, §3.4)
        let snap = dir.with_extension("snap");
        let _ = std::fs::remove_dir_all(&snap);
        let method = mgr.snapshot(&snap)?;
        println!("snapshot -> {} ({method:?})", snap.display());
        mgr.close()?;
        let _ = std::fs::remove_dir_all(&snap);
    }

    let _ = std::fs::remove_dir_all(&dir);
    println!("quickstart OK");
    Ok(())
}

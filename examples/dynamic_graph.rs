//! Dynamic graph construction across allocators — a runnable, small
//! instance of the paper's Fig 4 experiment (the full sweep lives in
//! `cargo bench --bench fig4_dynamic_graph`).
//!
//! Run: `cargo run --release --example dynamic_graph -- [--scale 14]
//!       [--threads 4] [--device optane]`

use metall_rs::bench_util::{BenchArgs, Table};
use metall_rs::experiments::fig4::{run, Fig4Params};
use metall_rs::util::human;
use metall_rs::util::tmp::TempDir;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let p = Fig4Params {
        scales: vec![args.get_usize("scale", 14) as u32],
        threads: args.get_usize("threads", 4),
        edge_factor: args.get_usize("edge-factor", 16),
        device: args.get("device").unwrap_or("optane").to_string(),
        ..Default::default()
    };
    let work = TempDir::new("dynamic-graph");
    println!(
        "dynamic graph construction: R-MAT SCALE {} ({} directed inserts), {} threads, device={}",
        p.scales[0],
        2 * (1u64 << p.scales[0]) * p.edge_factor as u64,
        p.threads,
        p.device,
    );
    let mut table = Table::new(&["allocator", "time", "edges/s", "vs metall"]);
    let rows = run(&p, work.path(), |r| {
        println!("  {:<20} {}", r.allocator, human::duration(r.secs));
    })?;
    let metall = rows.iter().find(|r| r.allocator == "metall").unwrap().secs;
    for r in &rows {
        table.row(&[
            r.allocator.to_string(),
            human::duration(r.secs),
            human::rate(r.edges_per_sec),
            format!("{:.2}x", r.secs / metall),
        ]);
    }
    table.print(&format!("Fig 4 (single point, SCALE {})", p.scales[0]));
    Ok(())
}

//! Incremental graph construction on simulated network file systems —
//! a runnable, small instance of the paper's Fig 5/6 experiment.
//!
//! Run: `cargo run --release --example incremental_graph --
//!       [--months 6] [--fs vast] [--dataset wiki]`

use metall_rs::bench_util::{BenchArgs, Table};
use metall_rs::experiments::fig5::{run_cell, Fig5Params, IoMode};
use metall_rs::util::human;
use metall_rs::util::tmp::TempDir;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let fs = args.get("fs").unwrap_or("vast").to_string();
    let dataset = args.get("dataset").unwrap_or("wiki").to_string();
    let p = Fig5Params {
        months: args.get_usize("months", 6) as u32,
        first_month_edges: args.get_usize("first-month", 20_000),
        ..Default::default()
    };
    let work = TempDir::new("incremental");
    println!(
        "incremental construction: {dataset}-like stream, {} months, fs={fs} (simulated)",
        p.months
    );

    let mut table = Table::new(&["mode", "ingest", "flush", "total"]);
    for mode in IoMode::all() {
        let rows = run_cell(&fs, &dataset, mode, &p, work.path())?;
        let ingest: f64 = rows.iter().map(|r| r.ingest_secs).sum();
        let flush: f64 = rows.iter().map(|r| r.flush_secs).sum();
        println!("  {:<14} cumulative:", mode.name());
        let mut cum = 0.0;
        for r in &rows {
            cum += r.ingest_secs + r.flush_secs;
            println!("    month {:<2} -> {}", r.month, human::duration(cum));
        }
        table.row(&[
            mode.name().to_string(),
            human::duration(ingest),
            human::duration(flush),
            human::duration(ingest + flush),
        ]);
    }
    table.print(&format!("Fig 6 breakdown ({dataset} on {fs})"));
    Ok(())
}

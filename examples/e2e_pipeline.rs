//! END-TO-END driver: proves all three layers compose on a real small
//! workload.
//!
//!   1. L3 rust coordinator streams a Wikipedia-like timestamped edge
//!      stream (monthly chunks) through the batched, bank-sharded
//!      ingestion pipeline into a persistent Metall datastore,
//!      snapshot-flushing after every month.
//!   2. The process "restarts": the datastore is reattached read-only —
//!      no reconstruction, no deserialization.
//!   3. The graph is handed to the AOT-compiled analytics engine
//!      (L2 JAX model + L1 Pallas kernels, executed via PJRT from rust —
//!      Python is not running) for PageRank and BFS, cross-checked
//!      against the pure-rust oracle.
//!
//! Headline metrics (EXPERIMENTS.md records a run): ingestion edges/s,
//! reattach time vs ingest time, analytics time per PageRank iteration.
//!
//! Run: `make artifacts && cargo run --release --example e2e_pipeline`

use std::time::Instant;

use metall_rs::alloc::MetallManager;
use metall_rs::containers::BankedAdjacency;
use metall_rs::coordinator::metrics::Metrics;
use metall_rs::coordinator::pipeline::{ingest, PipelineConfig};
use metall_rs::graph::ell::EllGraph;
use metall_rs::graph::stream::StreamConfig;
use metall_rs::runtime::engine::AnalyticsEngine;
use metall_rs::util::human;

fn main() -> anyhow::Result<()> {
    let args = metall_rs::bench_util::BenchArgs::parse();
    let months = args.get_usize("months", 6) as u32;
    let first = args.get_usize("first-month", 30_000);
    let artifacts = args.get("artifacts").unwrap_or("artifacts").to_string();

    let dir = std::env::temp_dir().join(format!("metallrs-e2e-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // ---------------- phase 1: streaming ingestion (L3) ----------------
    let stream = StreamConfig::wiki_like(months, first);
    println!(
        "[1/3] ingesting wiki-like stream: {} months, {} edges total",
        months,
        stream.total_edges()
    );
    let metrics = Metrics::new();
    let t_ingest = Instant::now();
    let mut total_edges = 0u64;
    {
        let mgr = MetallManager::create(&dir)?;
        let graph = BankedAdjacency::create(&mgr, 1024)?;
        mgr.construct::<u64>("graph", graph.offset())?;
        let cfg = PipelineConfig::default();
        for batch in stream.generate() {
            let rep = ingest(
                &mgr,
                &graph,
                batch.edges.iter().copied(),
                &cfg,
                true,
                &metrics,
            )?;
            total_edges += rep.edges;
            mgr.sync()?; // monthly snapshot-consistency point
            println!(
                "    month {:>2}: +{:>8} edges  ({})",
                batch.month,
                rep.edges,
                human::rate(rep.edges_per_sec)
            );
        }
        mgr.close()?;
    }
    let ingest_secs = t_ingest.elapsed().as_secs_f64();
    println!(
        "    ingested {total_edges} edges in {} ({})",
        human::duration(ingest_secs),
        human::rate(total_edges as f64 / ingest_secs)
    );

    // ------------- phase 2: reattach (no reconstruction) --------------
    println!("[2/3] reattaching datastore…");
    let t_attach = Instant::now();
    let mgr = MetallManager::open_read_only(&dir)?;
    let graph = BankedAdjacency::open(&mgr, mgr.read(mgr.find::<u64>("graph")?.unwrap()));
    let attach_secs = t_attach.elapsed().as_secs_f64();
    println!(
        "    reattached {} vertices / {} edges in {} ({}x faster than ingest)",
        graph.num_vertices(&mgr),
        graph.num_edges(&mgr),
        human::duration(attach_secs),
        (ingest_secs / attach_secs).round()
    );

    // ------ phase 3: analytics through PJRT (L2 JAX + L1 Pallas) ------
    println!("[3/3] analytics via AOT artifacts ({artifacts})…");
    let edges = graph.to_edge_list(&mgr);
    let n = edges.iter().map(|&(s, d)| s.max(d) + 1).max().unwrap_or(1) as usize;
    let ell = EllGraph::from_edges(n, &edges, 32);
    let engine = AnalyticsEngine::new(&artifacts)?;

    let pr = engine.pagerank(&ell, 30, 1e-7)?;
    println!(
        "    pagerank: {} iters in {} ({} per iter; compile {})",
        pr.iterations,
        human::duration(pr.exec_secs),
        human::duration(pr.exec_secs / pr.iterations as f64),
        human::duration(pr.compile_secs),
    );
    // cross-check against the pure-rust oracle
    let native = ell.pagerank_native(0.85, pr.iterations);
    let max_err = pr
        .values
        .iter()
        .zip(&native)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("    pagerank max |pjrt - native| = {max_err:.2e}");
    assert!(max_err < 1e-4, "analytics mismatch");

    let bfs = engine.bfs(&ell, 0)?;
    let reached = bfs.values.iter().filter(|&&l| l >= 0.0).count();
    println!(
        "    bfs: {} levels, {}/{} reachable, {}",
        bfs.iterations,
        reached,
        n,
        human::duration(bfs.exec_secs)
    );

    let top = {
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| pr.values[b].partial_cmp(&pr.values[a]).unwrap());
        idx[0]
    };
    println!("    top vertex by rank: {top} (rank {:.6})", pr.values[top]);

    mgr.close()?;
    let _ = std::fs::remove_dir_all(&dir);
    println!("e2e OK — L3 ingest → persistent store → reattach → L2/L1 analytics");
    Ok(())
}

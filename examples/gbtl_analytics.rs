//! The GBTL case study (paper §7, Fig 7/Fig 8): construct the four SNAP
//! stand-in graphs with and without Metall, then show that reattach +
//! analyze beats reconstruct + analyze.
//!
//! Run: `cargo run --release --example gbtl_analytics`

use metall_rs::bench_util::Table;
use metall_rs::experiments::fig7;
use metall_rs::util::human;
use metall_rs::util::tmp::TempDir;

fn main() -> anyhow::Result<()> {
    let work = TempDir::new("gbtl-analytics");
    println!("GBTL + Metall case study (4 SNAP-like datasets)…");
    let rows = fig7::run(work.path(), |r| {
        println!("  {} done", r.dataset);
    })?;

    let mut t7 = Table::new(&["dataset", "base (DRAM)", "GBTL+Metall (disk)", "ratio"]);
    for r in &rows {
        t7.row(&[
            r.dataset.to_string(),
            human::duration(r.base_construct),
            human::duration(r.metall_construct),
            format!("{:.2}x", r.metall_construct / r.base_construct),
        ]);
    }
    t7.print("Fig 7: graph construction time");

    let mut t8a = Table::new(&["dataset", "base (construct+BFS)", "metall (reattach+BFS)", "speedup"]);
    for r in &rows {
        t8a.row(&[
            r.dataset.to_string(),
            human::duration(r.base_bfs_total),
            human::duration(r.metall_bfs_total),
            format!("{:.1}x", r.base_bfs_total / r.metall_bfs_total),
        ]);
    }
    t8a.print("Fig 8a: BFS analytics time");

    let mut t8b = Table::new(&["dataset", "base (construct+PR)", "metall (reattach+PR)", "speedup"]);
    for r in &rows {
        t8b.row(&[
            r.dataset.to_string(),
            human::duration(r.base_pr_total),
            human::duration(r.metall_pr_total),
            format!("{:.1}x", r.base_pr_total / r.metall_pr_total),
        ]);
    }
    t8b.print("Fig 8b: PageRank analytics time");
    Ok(())
}

"""Property-testing shim: re-export `hypothesis` when it is installed,
otherwise provide a minimal deterministic stand-in so the test suite runs
in the offline build image (which carries numpy/jax but no hypothesis).

The stand-in supports exactly what these tests use — `@settings`,
`@given` with keyword strategies, `st.integers(lo, hi)` and
`st.sampled_from(seq)` — and replays each test over a fixed number of
seeded pseudo-random samples, so failures reproduce bit-identically.
"""

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng):
            return self._sample(rng)

    class _StrategiesModule:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(options):
            opts = list(options)
            return _Strategy(lambda rng: opts[rng.randrange(len(opts))])

    strategies = _StrategiesModule()

    def settings(max_examples=20, deadline=None, **_ignored):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                # @settings sits above @given, so it annotates the wrapper
                n = getattr(wrapper, "_compat_max_examples", 20)
                rng = random.Random(0xC0FFEE)
                for _ in range(n):
                    drawn = {k: s.sample(rng) for k, s in strats.items()}
                    fn(**drawn)

            # pytest must not mistake the original params for fixtures
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco

"""Test-path setup: make the `compile` package (python/) importable no
matter where pytest is launched from."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

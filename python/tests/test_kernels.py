"""L1 Pallas kernels vs. the pure-numpy oracle (ref.py).

hypothesis sweeps shapes and values; these are the core correctness
signal for everything the rust runtime later executes.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from compile.kernels import ell_rowsum, ell_rowmax, edge_bucket
from compile.kernels import ref


def _mk(f, w, seed):
    rng = np.random.default_rng(seed)
    gathered = rng.standard_normal((f, w), dtype=np.float32)
    values = (rng.random((f, w)) < 0.5).astype(np.float32)
    return gathered, values


@pytest.mark.parametrize("f,w", [(128, 32), (256, 16), (1024, 32), (128, 1), (128, 64)])
def test_rowsum_matches_ref(f, w):
    g, v = _mk(f, w, 1)
    out = np.asarray(ell_rowsum(g, v))
    np.testing.assert_allclose(out, ref.ell_rowsum_ref(g, v), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("f,w", [(128, 32), (256, 16), (1024, 32), (128, 1)])
def test_rowmax_matches_ref(f, w):
    g, v = _mk(f, w, 2)
    out = np.asarray(ell_rowmax(g, v))
    np.testing.assert_allclose(out, ref.ell_rowmax_ref(g, v), rtol=1e-6, atol=0)


@settings(max_examples=25, deadline=None)
@given(
    fb=st.integers(1, 8),
    w=st.sampled_from([1, 2, 8, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
    rb=st.sampled_from([16, 32, 128]),
)
def test_rowsum_hypothesis_shapes(fb, w, seed, rb):
    f = fb * rb
    g, v = _mk(f, w, seed)
    out = np.asarray(ell_rowsum(g, v, row_block=rb))
    np.testing.assert_allclose(out, ref.ell_rowsum_ref(g, v), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    fb=st.integers(1, 8),
    w=st.sampled_from([1, 2, 8, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
    rb=st.sampled_from([16, 32, 128]),
)
def test_rowmax_hypothesis_shapes(fb, w, seed, rb):
    f = fb * rb
    g, v = _mk(f, w, seed)
    out = np.asarray(ell_rowmax(g, v, row_block=rb))
    np.testing.assert_allclose(out, ref.ell_rowmax_ref(g, v), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1024, 4096]),
    nbanks=st.sampled_from([64, 256, 1024]),
    seed=st.integers(0, 2**31 - 1),
)
def test_edge_bucket_hypothesis(b, nbanks, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, 2**32, size=b, dtype=np.uint32)
    out = np.asarray(edge_bucket(src, nbanks))
    np.testing.assert_array_equal(out, ref.edge_bucket_ref(src, nbanks))
    assert out.max() < nbanks


def test_bucket_spread():
    # the hash must actually spread sequential ids across banks
    src = np.arange(4096, dtype=np.uint32)
    out = np.asarray(edge_bucket(src, 1024))
    counts = np.bincount(out, minlength=1024)
    assert counts.max() <= 24, "suspiciously lumpy bank distribution"


def test_rowsum_extreme_values():
    g = np.array([[1e30, -1e30], [np.float32(3.4e38), 0.0]], dtype=np.float32)
    g = np.repeat(g, 64, axis=0)  # 128 rows
    v = np.ones_like(g)
    out = np.asarray(ell_rowsum(g, v))
    np.testing.assert_allclose(out, ref.ell_rowsum_ref(g, v), rtol=1e-6)

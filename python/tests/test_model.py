"""L2 model steps vs. the numpy oracle, on random small graphs, plus
full-algorithm convergence checks (power iteration vs. dense PageRank,
BFS levels vs. a CPU BFS)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from compile import model
from compile.kernels import ref

W = 8
RB = 16  # row block override keeps hypothesis shapes small


def random_graph_fragments(n, avg_deg, seed, w=W):
    """Build a random directed graph in fragment-ELL form."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg)
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    # in-neighbors per vertex
    in_nbrs = [[] for _ in range(n)]
    outdeg = np.zeros(n, dtype=np.int64)
    for s, d in zip(src, dst):
        in_nbrs[d].append(s)
        outdeg[s] += 1
    frags_idx, frags_val, owner = [], [], []
    for v_id in range(n):
        nbrs = in_nbrs[v_id]
        for i in range(0, max(len(nbrs), 1), w):
            chunk = nbrs[i : i + w]
            row = np.zeros(w, dtype=np.int32)
            val = np.zeros(w, dtype=np.float32)
            row[: len(chunk)] = chunk
            val[: len(chunk)] = 1.0
            frags_idx.append(row)
            frags_val.append(val)
            owner.append(v_id)
    # pad fragment count to a multiple of RB, owned by vertex 0 with 0 vals
    while len(owner) % RB != 0:
        frags_idx.append(np.zeros(w, dtype=np.int32))
        frags_val.append(np.zeros(w, dtype=np.float32))
        owner.append(0)
    ell_idx = np.stack(frags_idx)
    ell_val = np.stack(frags_val)
    owner = np.asarray(owner, dtype=np.int32)
    inv_outdeg = np.where(outdeg > 0, 1.0 / np.maximum(outdeg, 1), 0.0).astype(np.float32)
    dangling = (outdeg == 0).astype(np.float32)
    return ell_idx, ell_val, owner, inv_outdeg, dangling, (src, dst), outdeg


def model_pagerank_step(ranks, g, n, alpha=0.85):
    ell_idx, ell_val, owner, inv_outdeg, dangling = g[:5]
    base = np.full(n, (1.0 - alpha) / n, dtype=np.float32)
    dweight = np.full(n, alpha / n, dtype=np.float32)
    return np.asarray(
        model.pagerank_step(
            ranks, ell_idx, ell_val, owner, inv_outdeg, dangling, base, dweight,
            n=n, alpha=alpha,
        )
    )


@pytest.mark.parametrize("n,avg_deg,seed", [(64, 2.0, 0), (128, 4.0, 1), (200, 1.0, 2)])
def test_pagerank_step_matches_ref(n, avg_deg, seed):
    g = random_graph_fragments(n, avg_deg, seed)
    ranks = np.full(n, 1.0 / n, dtype=np.float32)
    got = model_pagerank_step(ranks, g, n)
    want = ref.pagerank_step_ref(ranks, g[0], g[1], g[2], g[3], g[4], n, 0.85)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("n,avg_deg,seed", [(64, 2.0, 3), (128, 4.0, 4)])
def test_bfs_step_matches_ref(n, avg_deg, seed):
    g = random_graph_fragments(n, avg_deg, seed)
    ell_idx, ell_val, owner = g[0], g[1], g[2]
    frontier = np.zeros(n, dtype=np.float32)
    frontier[0] = 1.0
    visited = frontier.copy()
    for _ in range(3):
        got_f, got_v = model.bfs_step(frontier, visited, ell_idx, ell_val, owner, n=n)
        want_f, want_v = ref.bfs_step_ref(frontier, visited, ell_idx, ell_val, owner, n)
        np.testing.assert_array_equal(np.asarray(got_f), want_f)
        np.testing.assert_array_equal(np.asarray(got_v), want_v)
        frontier, visited = want_f, want_v


def test_pagerank_power_iteration_converges_to_dense():
    """Iterating the fragment model must converge to the dense-matrix
    PageRank — validates representation + semiring end to end."""
    n, seed, alpha = 96, 7, 0.85
    g = random_graph_fragments(n, 3.0, seed)
    (src, dst) = g[5]
    outdeg = g[6]
    # dense transition matrix
    P = np.zeros((n, n))
    for s, d in zip(src, dst):
        P[d, s] += 1.0 / outdeg[s]
    dang = (outdeg == 0).astype(float)
    ranks_dense = np.full(n, 1.0 / n)
    for _ in range(60):
        ranks_dense = (1 - alpha) / n + alpha * (P @ ranks_dense + np.dot(ranks_dense, dang) / n)
    ranks = np.full(n, 1.0 / n, dtype=np.float32)
    for _ in range(60):
        ranks = model_pagerank_step(ranks, g, n, alpha)
    np.testing.assert_allclose(ranks, ranks_dense, rtol=2e-3, atol=1e-6)
    np.testing.assert_allclose(ranks.sum(), 1.0, rtol=1e-3)


def test_bfs_levels_match_cpu_bfs():
    n, seed = 128, 11
    g = random_graph_fragments(n, 3.0, seed)
    (src, dst) = g[5]
    # CPU BFS on the directed graph
    from collections import deque
    adj = [[] for _ in range(n)]
    for s, d in zip(src, dst):
        adj[s].append(d)
    level = np.full(n, -1)
    level[0] = 0
    q = deque([0])
    while q:
        u = q.popleft()
        for v_ in adj[u]:
            if level[v_] < 0:
                level[v_] = level[u] + 1
                q.append(v_)
    # model BFS
    frontier = np.zeros(n, dtype=np.float32)
    frontier[0] = 1.0
    visited = frontier.copy()
    got_level = np.full(n, -1)
    got_level[0] = 0
    lvl = 0
    while frontier.sum() > 0 and lvl < n:
        lvl += 1
        frontier, visited = (
            np.asarray(x)
            for x in model.bfs_step(frontier, visited, g[0], g[1], g[2], n=n)
        )
        got_level[(frontier > 0) & (got_level < 0)] = lvl
    np.testing.assert_array_equal(got_level, level)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(16, 96), avg=st.floats(0.5, 4.0), seed=st.integers(0, 10**6))
def test_pagerank_step_mass_conservation(n, avg, seed):
    """sum(new_ranks) == 1 when sum(ranks) == 1 (stochastic step)."""
    g = random_graph_fragments(n, avg, seed)
    ranks = np.random.default_rng(seed).random(n).astype(np.float32)
    ranks /= ranks.sum()
    out = model_pagerank_step(ranks, g, n, 0.85)
    np.testing.assert_allclose(out.sum(), 1.0, rtol=2e-3)

"""AOT compiler: lower the L2 models to HLO *text* under ``artifacts/``.

HLO text — NOT ``lowered.compile()`` serialization — is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that
the rust side's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Emits one ``.hlo.txt`` per (model, shape-variant) plus ``manifest.txt``:

    pagerank <n> <f> <w> <alpha> <file>
    bfs      <n> <f> <w> -       <file>
    bucket   <batch> <nbanks> -  - <file>

The rust runtime (`runtime::manifest`) parses this ladder and picks the
smallest variant that fits a given graph, padding inputs.

Usage: python -m compile.aot --outdir ../artifacts [--quick]
"""

import argparse
import pathlib
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import edge_bucket

# (n, f) ladder: n = padded vertex count, f = padded fragment count.
# f is a multiple of the kernels' ROW_BLOCK (128) and n; W is fixed.
ELL_W = 32
VARIANTS = [
    # (n, f)
    (256, 256),
    (256, 1024),
    (1024, 1024),
    (1024, 4096),
    (4096, 4096),
    (4096, 16384),
    (16384, 16384),
    (16384, 65536),
]
QUICK_VARIANTS = [(256, 256), (1024, 1024)]

BUCKET_BATCHES = [4096, 65536]
BUCKET_NBANKS = 1024
ALPHA = model.DEFAULT_ALPHA


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_pagerank(n, f, w):
    fn = lambda *args: model.pagerank_step(*args, n=n, alpha=ALPHA)
    return jax.jit(fn).lower(*model.pagerank_example_args(n, f, w))


def lower_bfs(n, f, w):
    fn = lambda *args: model.bfs_step(*args, n=n)
    return jax.jit(fn).lower(*model.bfs_example_args(n, f, w))


def lower_bucket(batch, nbanks):
    fn = lambda src: edge_bucket(src, nbanks)
    return jax.jit(fn).lower(jax.ShapeDtypeStruct((batch,), jnp.uint32))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="small ladder (CI)")
    args = ap.parse_args(argv)

    outdir = pathlib.Path(args.outdir)
    outdir.mkdir(parents=True, exist_ok=True)
    manifest = []

    variants = QUICK_VARIANTS if args.quick else VARIANTS
    for n, f in variants:
        for kind, lower in (("pagerank", lower_pagerank), ("bfs", lower_bfs)):
            name = f"{kind}_n{n}_f{f}_w{ELL_W}.hlo.txt"
            text = to_hlo_text(lower(n, f, ELL_W))
            (outdir / name).write_text(text)
            alpha = f"{ALPHA}" if kind == "pagerank" else "-"
            manifest.append(f"{kind} {n} {f} {ELL_W} {alpha} {name}")
            print(f"  wrote {name} ({len(text)} chars)", file=sys.stderr)

    for batch in BUCKET_BATCHES:
        name = f"bucket_b{batch}_m{BUCKET_NBANKS}.hlo.txt"
        text = to_hlo_text(lower_bucket(batch, BUCKET_NBANKS))
        (outdir / name).write_text(text)
        manifest.append(f"bucket {batch} {BUCKET_NBANKS} - - {name}")
        print(f"  wrote {name} ({len(text)} chars)", file=sys.stderr)

    (outdir / "manifest.txt").write_text("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} artifacts to {outdir}", file=sys.stderr)


if __name__ == "__main__":
    main()

"""Layer-2 JAX models: GraphBLAS analytic steps over the fragment-ELL
graph representation, calling the L1 Pallas kernels.

Representation (see DESIGN.md §2): a graph with ``n`` vertices is stored
as ``F`` *row fragments* of width ``W``. Fragment ``f`` holds up to ``W``
in-neighbor ids of vertex ``owner[f]`` in ``ell_idx[f, :]`` with validity
mask ``ell_val[f, :]`` (0.0 padding). High-degree vertices span several
fragments; per-vertex results are recovered with a segment reduction,
which XLA lowers to a scatter — together with ``jnp.take`` for the
gather, the irregular accesses stay in XLA native ops while the dense
semiring arithmetic runs in the Pallas kernels.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import ell_rowsum, ell_rowmax

DEFAULT_ALPHA = 0.85


@functools.partial(jax.jit, static_argnames=("n", "alpha"))
def pagerank_step(
    ranks, ell_idx, ell_val, owner, inv_outdeg, dangling, base, dweight, *, n, alpha=DEFAULT_ALPHA
):
    """One PageRank pull iteration.

    new[i] = base[i] + alpha * sum_{j->i} ranks[j]/outdeg[j] + D * dweight[i]

    For an unpadded graph of n_true vertices, ``base = (1-alpha)/n_true``
    and ``dweight = alpha/n_true`` everywhere, recovering the textbook
    update. The vectors are *runtime inputs* (not baked-in constants) so
    that the AOT shape ladder can pad a graph of n_true vertices up to a
    compiled variant of n ≥ n_true **exactly**: padded vertices get
    base = dweight = inv_outdeg = dangling = 0 and therefore stay at rank
    0 forever, leaving real vertices' ranks bit-identical in expectation
    to the unpadded computation.

    Args:
      ranks:      f32[n]    current PageRank vector.
      ell_idx:    i32[F, W] in-neighbor ids per fragment.
      ell_val:    f32[F, W] 1.0 for a real edge, 0.0 for padding.
      owner:      i32[F]    owning vertex of each fragment.
      inv_outdeg: f32[n]    1/outdeg (0 for dangling vertices).
      dangling:   f32[n]    1.0 where outdeg == 0 (real vertices only).
      base:       f32[n]    teleport term per vertex.
      dweight:    f32[n]    dangling redistribution weight per vertex.
    Returns:
      f32[n] updated ranks.
    """
    contrib = ranks * inv_outdeg
    gathered = jnp.take(contrib, ell_idx, axis=0)
    frag = ell_rowsum(gathered, ell_val)
    per_vertex = jax.ops.segment_sum(frag, owner, num_segments=n)
    dangling_mass = jnp.dot(ranks, dangling)
    return base + alpha * per_vertex + dangling_mass * dweight


@functools.partial(jax.jit, static_argnames=("n",))
def bfs_step(frontier, visited, ell_idx, ell_val, owner, *, n):
    """One BFS pull expansion on 0/1 float masks.

    Returns (next_frontier, visited') with
      next_frontier[i] = (OR_{j->i} frontier[j]) AND NOT visited[i]
      visited'         = visited OR next_frontier
    """
    gathered = jnp.take(frontier, ell_idx, axis=0)
    frag = ell_rowmax(gathered, ell_val)
    hit = jax.ops.segment_max(frag, owner, num_segments=n)
    hit = jnp.maximum(hit, 0.0)  # segment_max fills empty segments with -inf
    nxt = jnp.minimum(hit, 1.0) * (1.0 - visited)
    vis = jnp.minimum(visited + nxt, 1.0)
    return nxt, vis


def pagerank_example_args(n, f, w):
    """ShapeDtypeStructs for AOT lowering of `pagerank_step`."""
    s = jax.ShapeDtypeStruct
    return (
        s((n,), jnp.float32),      # ranks
        s((f, w), jnp.int32),      # ell_idx
        s((f, w), jnp.float32),    # ell_val
        s((f,), jnp.int32),        # owner
        s((n,), jnp.float32),      # inv_outdeg
        s((n,), jnp.float32),      # dangling
        s((n,), jnp.float32),      # base
        s((n,), jnp.float32),      # dweight
    )


def bfs_example_args(n, f, w):
    """ShapeDtypeStructs for AOT lowering of `bfs_step`."""
    s = jax.ShapeDtypeStruct
    return (
        s((n,), jnp.float32),      # frontier
        s((n,), jnp.float32),      # visited
        s((f, w), jnp.int32),      # ell_idx
        s((f, w), jnp.float32),    # ell_val
        s((f,), jnp.int32),        # owner
    )

"""Pure-jnp/numpy oracles for every kernel and model function.

These are the correctness ground truth: pytest (incl. hypothesis sweeps)
asserts the Pallas kernels and the AOT-lowered models match these within
float tolerance.
"""

import numpy as np


def ell_rowsum_ref(gathered, values):
    return np.sum(np.asarray(gathered) * np.asarray(values), axis=1)


def ell_rowmax_ref(gathered, values):
    return np.max(np.asarray(gathered) * np.asarray(values), axis=1)


def edge_bucket_ref(src, nbanks):
    """murmur3 fmix32 & (nbanks-1) — mirrors rust graph::bucket_hash32."""
    h = np.asarray(src, dtype=np.uint32).copy()
    h ^= h >> np.uint32(16)
    h *= np.uint32(0x85EBCA6B)
    h ^= h >> np.uint32(13)
    h *= np.uint32(0xC2B2AE35)
    h ^= h >> np.uint32(16)
    return h & np.uint32(nbanks - 1)


def segment_sum_ref(data, owner, n):
    out = np.zeros(n, dtype=np.asarray(data).dtype)
    np.add.at(out, np.asarray(owner), np.asarray(data))
    return out


def segment_max_ref(data, owner, n):
    out = np.zeros(n, dtype=np.asarray(data).dtype)
    np.maximum.at(out, np.asarray(owner), np.asarray(data))
    return out


def pagerank_step_ref(
    ranks, ell_idx, ell_val, owner, inv_outdeg, dangling, n, alpha, base=None, dweight=None
):
    """One PageRank pull iteration over the fragment representation.

    new[i] = base[i] + alpha * sum_{j->i} ranks[j]/outdeg[j] + D*dweight[i]
    with base = (1-alpha)/n and dweight = alpha/n by default (textbook).
    """
    ranks = np.asarray(ranks, dtype=np.float64)
    if base is None:
        base = np.full(n, (1.0 - alpha) / n)
    if dweight is None:
        dweight = np.full(n, alpha / n)
    contrib = ranks * np.asarray(inv_outdeg, dtype=np.float64)
    gathered = contrib[np.asarray(ell_idx)]
    frag = np.sum(gathered * np.asarray(ell_val, dtype=np.float64), axis=1)
    per_vertex = segment_sum_ref(frag, owner, n)
    dangling_mass = float(np.dot(ranks, np.asarray(dangling, dtype=np.float64)))
    out = np.asarray(base, dtype=np.float64) + alpha * per_vertex \
        + dangling_mass * np.asarray(dweight, dtype=np.float64)
    return out.astype(np.float32)


def bfs_step_ref(frontier, visited, ell_idx, ell_val, owner, n):
    """One BFS pull expansion step on 0/1 float masks.

    hit[i]   = OR_{j->i} frontier[j]
    next[i]  = hit[i] AND NOT visited[i]
    visited' = visited OR next
    """
    frontier = np.asarray(frontier, dtype=np.float32)
    visited = np.asarray(visited, dtype=np.float32)
    gathered = frontier[np.asarray(ell_idx)]
    frag = np.max(gathered * np.asarray(ell_val, dtype=np.float32), axis=1)
    hit = segment_max_ref(frag, owner, n)
    nxt = np.minimum(hit, 1.0) * (1.0 - visited)
    vis = np.minimum(visited + nxt, 1.0)
    return nxt, vis

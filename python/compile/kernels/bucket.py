"""Edge→bank bucketing kernel used by the ingestion batcher.

The L3 coordinator shards incoming edges across ``m`` banks by a hash of
the source vertex (paper §6.1: a bank is an adjacency list + mutex pair).
This kernel computes the bank assignment for a whole edge batch in one
AOT-compiled call; the rust pipeline uses it when a PJRT engine is
attached (and falls back to the identical native hash otherwise — the
two are bit-equal, which the tests assert).

Hash: the splitmix64 finalizer truncated to 32-bit lanes (two rounds of
multiply–xorshift), masked to the (power-of-two) bank count.
"""

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BATCH_BLOCK = 1024

# murmur3 fmix32 constants — numpy scalars so pallas treats them as
# literals rather than captured traced constants.
M1 = np.uint32(0x85EBCA6B)
M2 = np.uint32(0xC2B2AE35)


def _bucket_kernel(nbanks, src_ref, o_ref):
    h = src_ref[...]
    h = h ^ (h >> 16)
    h = h * M1
    h = h ^ (h >> 13)
    h = h * M2
    h = h ^ (h >> 16)
    o_ref[...] = h & np.uint32(nbanks - 1)


@functools.partial(jax.jit, static_argnames=("nbanks", "batch_block"))
def edge_bucket(src, nbanks, batch_block=None):
    """bank[i] = murmur3_fmix32(src[i]) & (nbanks-1). nbanks power of 2."""
    assert nbanks & (nbanks - 1) == 0, "nbanks must be a power of two"
    (b,) = src.shape
    bb = min(batch_block or BATCH_BLOCK, b)
    assert b % bb == 0, f"B={b} not a multiple of batch block {bb}"
    return pl.pallas_call(
        functools.partial(_bucket_kernel, nbanks),
        grid=(b // bb,),
        in_specs=[pl.BlockSpec((bb,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.uint32),
        interpret=True,
    )(src)

"""ELL-fragment semiring row reductions — the analytics hot spot.

The graph is stored as *row fragments*: each fragment owns at most ``W``
neighbors of one vertex (high-degree vertices are split across several
fragments; the L2 model aggregates fragment results with a segment-sum).
The kernels below consume

  - ``gathered``: (F, W) f32 — neighbor values already gathered
    (``contrib[ell_idx]``; the irregular gather stays in XLA where the
    backend has a native implementation),
  - ``values``:   (F, W) f32 — semiring edge values; 0.0 marks padding,

and produce the per-fragment reduction:

  - ``ell_rowsum``: plus-times semiring (PageRank),
  - ``ell_rowmax``: max-times semiring == boolean or-and on 0/1 floats
    (BFS frontier expansion).

TPU adaptation (DESIGN.md §Hardware-Adaptation): the grid walks row
blocks of ``ROW_BLOCK`` fragments; each grid step holds a
(ROW_BLOCK, W) tile of both operands in VMEM — 2·8192·32·4 B = 2 MiB,
which double-buffers comfortably inside a TensorCore's ~16 MiB VMEM —
and the reduction runs across lanes on the VPU. SpMV is memory-bound
(arithmetic intensity ≈ 0.25 flop/byte), so block shapes are chosen for
streaming, not MXU occupancy.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows (fragments) per grid step (tuned: 128 -> 8192 gave 13x on the CPU
# interpret path by cutting grid-loop trip count; see EXPERIMENTS.md
# §Perf). F smaller than this falls back to its largest pow2 divisor.
ROW_BLOCK = 8192


def _rowsum_kernel(g_ref, v_ref, o_ref):
    o_ref[...] = jnp.sum(g_ref[...] * v_ref[...], axis=1)


def _rowmax_kernel(g_ref, v_ref, o_ref):
    o_ref[...] = jnp.max(g_ref[...] * v_ref[...], axis=1)


def _largest_pow2_divisor(f):
    return f & -f


def _call(kernel, gathered, values, *, row_block=None):
    f, w = gathered.shape
    assert values.shape == (f, w), (gathered.shape, values.shape)
    if row_block is None:
        # AOT variants use F % ROW_BLOCK == 0; odd test shapes fall back
        # to the largest power-of-two divisor (possibly 1).
        rb = min(ROW_BLOCK, _largest_pow2_divisor(f))
    else:
        rb = min(row_block, f)
    assert f % rb == 0, f"F={f} not a multiple of row block {rb}"
    return pl.pallas_call(
        kernel,
        grid=(f // rb,),
        in_specs=[
            pl.BlockSpec((rb, w), lambda i: (i, 0)),
            pl.BlockSpec((rb, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((f,), gathered.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(gathered, values)


@functools.partial(jax.jit, static_argnames=("row_block",))
def ell_rowsum(gathered, values, row_block=None):
    """out[i] = sum_k gathered[i, k] * values[i, k]  (plus-times)."""
    return _call(_rowsum_kernel, gathered, values, row_block=row_block)


@functools.partial(jax.jit, static_argnames=("row_block",))
def ell_rowmax(gathered, values, row_block=None):
    """out[i] = max_k gathered[i, k] * values[i, k]  (or-and on 0/1)."""
    return _call(_rowmax_kernel, gathered, values, row_block=row_block)

"""Layer-1 Pallas kernels for the GraphBLAS analytics hot path.

All kernels are lowered with ``interpret=True`` — the CPU PJRT client the
rust runtime uses cannot execute real-TPU Mosaic custom calls (see
DESIGN.md §Hardware-Adaptation for the TPU tiling rationale).
"""

from .ell_spmv import ell_rowsum, ell_rowmax, ROW_BLOCK
from .bucket import edge_bucket

__all__ = ["ell_rowsum", "ell_rowmax", "edge_bucket", "ROW_BLOCK"]

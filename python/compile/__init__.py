"""Build-time compile path (L2 JAX models + L1 Pallas kernels).

Nothing in this package is imported at runtime; ``aot.py`` lowers the
models once to HLO text under ``artifacts/`` and the rust coordinator
executes them through the PJRT C API.
"""
